//! Objectives (S12): regularized empirical risk over linear models,
//! problem (1) of the paper:
//!
//!   f(w) = (1/n) Σ φ(y_i · x_iᵀ w) + (λ/2)‖w‖²,   f_i(w) = φ(m_i) + (λ/2)‖w‖²
//!
//! The paper evaluates the logistic loss; smoothed (squared) hinge and
//! squared loss are included because the paper's intro motivates both SVMs
//! and general ERM, and they exercise the same code paths with different
//! (L, μ) constants.
//!
//! The decomposition every optimizer here exploits:
//!   ∇f_i(w) = r_i(w) · x_i + λ w,   r_i(w) = φ′(y_i x_iᵀ w) · y_i
//! — an O(nnz) sparse dot for the margin, a scalar residual, and a dense
//! ridge term. The SVRG direction then needs only (r − r₀)·x_i sparse work
//! plus dense λ(u−u₀)+μ̄ streams (see `coordinator::worker`).

pub mod lipschitz;

use std::sync::Arc;

use crate::data::Dataset;
use crate::linalg::sparse;

/// Margin-loss family φ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    /// φ(m) = log(1 + e^{−m}) — the paper's experimental objective.
    Logistic,
    /// φ(m) = max(0, 1−m)² — smoothed hinge (SVM, differentiable).
    SquaredHinge,
    /// φ(m) = ½(1−m)² — least squares on the margin (ridge regression).
    Squared,
}

impl LossKind {
    /// Loss value at margin m (f64: summed over up to ~10⁵ instances).
    #[inline]
    pub fn phi(&self, m: f64) -> f64 {
        match self {
            LossKind::Logistic => m.max(0.0) - m + (-m.abs()).exp().ln_1p(),
            LossKind::SquaredHinge => {
                let t = (1.0 - m).max(0.0);
                t * t
            }
            LossKind::Squared => 0.5 * (1.0 - m) * (1.0 - m),
        }
    }

    /// Derivative dφ/dm at margin m.
    #[inline]
    pub fn dphi(&self, m: f32) -> f32 {
        match self {
            // −σ(−m), computed via the stable tanh form
            LossKind::Logistic => -(0.5 * (1.0 - (0.5 * m).tanh())),
            LossKind::SquaredHinge => -2.0 * (1.0 - m).max(0.0),
            LossKind::Squared => m - 1.0,
        }
    }

    /// Smoothness constant of φ (max |φ″|), entering L = c·max‖x‖² + λ.
    pub fn curvature(&self) -> f32 {
        match self {
            LossKind::Logistic => 0.25,
            LossKind::SquaredHinge => 2.0,
            LossKind::Squared => 1.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Logistic => "logistic",
            LossKind::SquaredHinge => "squared-hinge",
            LossKind::Squared => "squared",
        }
    }
}

/// f(w) over a CSR dataset with an L2 ridge — the paper's problem instance.
#[derive(Clone)]
pub struct Objective {
    pub data: Arc<Dataset>,
    pub lam: f32,
    pub kind: LossKind,
}

impl Objective {
    pub fn new(data: Arc<Dataset>, lam: f32, kind: LossKind) -> Self {
        Objective { data, lam, kind }
    }

    /// The paper's setup: logistic loss, λ = 1e-4.
    pub fn paper(data: Arc<Dataset>) -> Self {
        Objective::new(data, 1e-4, LossKind::Logistic)
    }

    pub fn n(&self) -> usize {
        self.data.n()
    }

    pub fn dim(&self) -> usize {
        self.data.dim
    }

    /// Margin m_i = y_i x_iᵀ w.
    #[inline]
    pub fn margin(&self, w: &[f32], i: usize) -> f32 {
        self.data.label(i) * self.data.row(i).dot_dense(w)
    }

    /// Residual r_i(w): the scalar such that ∇f_i = r_i x_i + λw.
    #[inline]
    pub fn residual(&self, w: &[f32], i: usize) -> f32 {
        self.kind.dphi(self.margin(w, i)) * self.data.label(i)
    }

    /// Residual with an arbitrary coordinate reader (lock-free shared reads).
    #[inline]
    pub fn residual_with<F: FnMut(usize) -> f32>(&self, read: F, i: usize) -> f32 {
        let row = self.data.row(i);
        let m = self.data.label(i) * sparse::dot_with(&row, read);
        self.kind.dphi(m) * self.data.label(i)
    }

    /// Full objective value f(w), f64-accumulated.
    pub fn loss(&self, w: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..self.n() {
            acc += self.kind.phi(self.margin(w, i) as f64);
        }
        let reg: f64 = w.iter().map(|&v| (v as f64) * (v as f64)).sum();
        acc / self.n() as f64 + 0.5 * self.lam as f64 * reg
    }

    /// Dense ∇f_i(w) into `out` (test/reference path — O(d)).
    pub fn grad_i_into(&self, w: &[f32], i: usize, out: &mut [f32]) {
        let r = self.residual(w, i);
        for (o, &wj) in out.iter_mut().zip(w.iter()) {
            *o = self.lam * wj;
        }
        self.data.row(i).axpy_into(r, out);
    }

    /// Full gradient ∇f(w) into `out`. Also returns all residuals r_i(w) —
    /// the epoch pass caches them so inner iterations get ∇f_i(u₀) in O(1)
    /// (the "compute the full gradient in parallel" step, Alg. 1).
    pub fn full_grad_into(&self, w: &[f32], out: &mut [f32], residuals: &mut Vec<f32>) {
        residuals.clear();
        residuals.reserve(self.n());
        for o in out.iter_mut() {
            *o = 0.0;
        }
        for i in 0..self.n() {
            let r = self.residual(w, i);
            residuals.push(r);
            self.data.row(i).axpy_into(r, out);
        }
        let inv_n = 1.0 / self.n() as f32;
        for (o, &wj) in out.iter_mut().zip(w.iter()) {
            *o = *o * inv_n + self.lam * wj;
        }
    }

    /// Range-restricted unnormalized gradient accumulation: Σ_{i∈range} r_i x_i
    /// into `out`, residuals recorded at their global index. This is one
    /// thread's share φ_a of the parallel full-gradient pass.
    pub fn grad_contrib_range(
        &self,
        w: &[f32],
        range: std::ops::Range<usize>,
        out: &mut [f32],
        residuals: &mut [f32],
    ) {
        for i in range {
            let r = self.residual(w, i);
            residuals[i] = r;
            self.data.row(i).axpy_into(r, out);
        }
    }

    /// μ-strong convexity modulus: the ridge guarantees μ = λ.
    pub fn strong_convexity(&self) -> f32 {
        self.lam
    }

    /// Smoothness bound L (see `lipschitz`).
    pub fn lipschitz(&self) -> f32 {
        lipschitz::lipschitz_bound(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    fn obj() -> Objective {
        let ds = SyntheticSpec::new("t", 64, 32, 8, 42).generate();
        Objective::paper(Arc::new(ds))
    }

    /// Finite-difference check of grad_i_into.
    #[test]
    fn grad_i_matches_finite_difference() {
        let o = obj();
        let mut w: Vec<f32> = (0..o.dim()).map(|j| ((j * 7 % 13) as f32 - 6.0) * 0.05).collect();
        let i = 5;
        let mut g = vec![0.0; o.dim()];
        o.grad_i_into(&w, i, &mut g);
        let f_i = |o: &Objective, w: &[f32]| -> f64 {
            let m = o.margin(w, i) as f64;
            let reg: f64 = w.iter().map(|&v| (v as f64) * (v as f64)).sum();
            o.kind.phi(m) + 0.5 * o.lam as f64 * reg
        };
        let eps = 1e-3f32;
        for j in (0..o.dim()).step_by(5) {
            let orig = w[j];
            w[j] = orig + eps;
            let fp = f_i(&o, &w);
            w[j] = orig - eps;
            let fm = f_i(&o, &w);
            w[j] = orig;
            let fd = ((fp - fm) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g[j]).abs() < 5e-3,
                "coord {j}: fd {fd} vs analytic {}",
                g[j]
            );
        }
    }

    #[test]
    fn full_grad_is_mean_of_instance_grads() {
        let o = obj();
        let w: Vec<f32> = (0..o.dim()).map(|j| (j as f32 * 0.01) - 0.1).collect();
        let mut full = vec![0.0; o.dim()];
        let mut res = Vec::new();
        o.full_grad_into(&w, &mut full, &mut res);
        let mut acc = vec![0.0f32; o.dim()];
        let mut gi = vec![0.0f32; o.dim()];
        for i in 0..o.n() {
            o.grad_i_into(&w, i, &mut gi);
            for j in 0..o.dim() {
                acc[j] += gi[j] / o.n() as f32;
            }
        }
        for j in 0..o.dim() {
            assert!((acc[j] - full[j]).abs() < 1e-5, "coord {j}");
        }
        assert_eq!(res.len(), o.n());
    }

    #[test]
    fn residual_cache_consistent() {
        let o = obj();
        let w: Vec<f32> = vec![0.05; o.dim()];
        let mut full = vec![0.0; o.dim()];
        let mut res = Vec::new();
        o.full_grad_into(&w, &mut full, &mut res);
        for i in 0..o.n() {
            assert_eq!(res[i], o.residual(&w, i));
        }
    }

    #[test]
    fn contrib_ranges_assemble_full_gradient() {
        let o = obj();
        let w: Vec<f32> = (0..o.dim()).map(|j| (j as f32).sin() * 0.1).collect();
        let mut want = vec![0.0; o.dim()];
        let mut res_want = Vec::new();
        o.full_grad_into(&w, &mut want, &mut res_want);

        // assemble from 3 disjoint ranges, as the parallel epoch pass does
        let mut acc = vec![0.0f32; o.dim()];
        let mut res = vec![0.0f32; o.n()];
        let n = o.n();
        for r in [0..n / 3, n / 3..2 * n / 3, 2 * n / 3..n] {
            let mut part = vec![0.0f32; o.dim()];
            o.grad_contrib_range(&w, r, &mut part, &mut res);
            for j in 0..o.dim() {
                acc[j] += part[j];
            }
        }
        let inv_n = 1.0 / n as f32;
        for j in 0..o.dim() {
            let assembled = acc[j] * inv_n + o.lam * w[j];
            assert!((assembled - want[j]).abs() < 1e-5);
        }
        assert_eq!(res, res_want);
    }

    #[test]
    fn loss_decreases_along_negative_full_gradient() {
        let o = obj();
        let w: Vec<f32> = vec![0.1; o.dim()];
        let mut g = vec![0.0; o.dim()];
        let mut res = Vec::new();
        o.full_grad_into(&w, &mut g, &mut res);
        let f0 = o.loss(&w);
        let w1: Vec<f32> = w.iter().zip(&g).map(|(&wj, &gj)| wj - 0.5 * gj).collect();
        assert!(o.loss(&w1) < f0);
    }

    #[test]
    fn all_loss_kinds_differentiable_consistency() {
        // dphi must be the derivative of phi for each kind (finite diff)
        for kind in [LossKind::Logistic, LossKind::SquaredHinge, LossKind::Squared] {
            for &m in &[-3.0f32, -0.5, 0.0, 0.9, 1.0, 1.1, 4.0] {
                let eps = 1e-3f64;
                let fd = (kind.phi(m as f64 + eps) - kind.phi(m as f64 - eps)) / (2.0 * eps);
                let an = kind.dphi(m) as f64;
                assert!(
                    (fd - an).abs() < 5e-3,
                    "{}: m={m} fd={fd} analytic={an}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn logistic_loss_at_zero_is_log2() {
        let o = obj();
        let w = vec![0.0; o.dim()];
        assert!((o.loss(&w) - (2.0f64).ln()).abs() < 1e-9);
    }
}
