//! Smoothness/convexity constants for the theory module (Assumptions 1–2).
//!
//! For a margin loss φ with |φ″| ≤ c and f_i(w) = φ(y_i x_iᵀ w) + (λ/2)‖w‖²:
//!   ‖∇f_i(a) − ∇f_i(b)‖ ≤ (c‖x_i‖² + λ)‖a − b‖
//! so L = c·maxᵢ‖x_i‖² + λ satisfies Assumption 1, and the ridge gives
//! μ = λ for Assumption 2. With L2-normalized rows (our preprocessing),
//! L = c + λ — e.g. the paper's logistic setup has L ≈ 0.2501, μ = 1e-4,
//! condition number L/μ ≈ 2.5e3.

use super::Objective;

/// Upper bound on the per-instance gradient Lipschitz constant L.
pub fn lipschitz_bound(obj: &Objective) -> f32 {
    obj.kind.curvature() * obj.data.max_row_sq_norm() + obj.lam
}

/// Condition number κ = L/μ.
pub fn condition_number(obj: &Objective) -> f64 {
    lipschitz_bound(obj) as f64 / obj.strong_convexity() as f64
}

/// Empirical check of Assumption 1 along random coordinate pairs:
/// returns max over trials of ‖∇f_i(a)−∇f_i(b)‖ / ‖a−b‖ (must be ≤ L).
pub fn empirical_lipschitz(obj: &Objective, trials: usize, seed: u64) -> f32 {
    use crate::util::rng::Pcg32;
    let mut rng = Pcg32::new(seed, 0x11b);
    let d = obj.dim();
    let mut worst = 0.0f32;
    let mut ga = vec![0.0f32; d];
    let mut gb = vec![0.0f32; d];
    for _ in 0..trials {
        let i = rng.below(obj.n());
        let a: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32 * 0.5).collect();
        let b: Vec<f32> = a.iter().map(|&x| x + rng.gaussian() as f32 * 0.1).collect();
        obj.grad_i_into(&a, i, &mut ga);
        obj.grad_i_into(&b, i, &mut gb);
        let num = crate::linalg::dense::dist2(&ga, &gb);
        let den = crate::linalg::dense::dist2(&a, &b);
        if den > 1e-12 {
            worst = worst.max((num / den) as f32);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::objective::LossKind;
    use std::sync::Arc;

    #[test]
    fn normalized_logistic_constants() {
        let ds = SyntheticSpec::new("t", 128, 64, 8, 1).generate();
        let o = Objective::paper(Arc::new(ds));
        let l = lipschitz_bound(&o);
        assert!((l - (0.25 + 1e-4)).abs() < 1e-3, "L={l}");
        assert_eq!(o.strong_convexity(), 1e-4);
        assert!((condition_number(&o) - l as f64 / 1e-4).abs() < 1.0);
    }

    #[test]
    fn empirical_never_exceeds_bound() {
        let ds = SyntheticSpec::new("t", 64, 32, 6, 2).generate();
        for kind in [LossKind::Logistic, LossKind::SquaredHinge, LossKind::Squared] {
            let o = Objective::new(Arc::new(ds.clone()), 1e-3, kind);
            let emp = empirical_lipschitz(&o, 200, 3);
            let bound = lipschitz_bound(&o);
            assert!(
                emp <= bound * 1.02,
                "{}: empirical {emp} > bound {bound}",
                kind.name()
            );
        }
    }
}
