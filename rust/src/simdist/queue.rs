//! Deterministic discrete-event queue keyed by `(time, seq)`.
//!
//! `seq` is a monotone insertion counter that breaks time ties, so the pop
//! order is a pure function of the push sequence — no dependence on heap
//! internals, payload contents, or float tie ambiguity. This is the same
//! tie-break discipline the single-box engine uses for its per-core phase
//! events (`simcore::engine`), lifted into a reusable generic container for
//! the cluster simulator (`crate::simdist`).

use std::collections::BinaryHeap;

struct Ev<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Ev<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for Ev<T> {}
impl<T> PartialOrd for Ev<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Ev<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap via reverse: earlier time (then lower seq) = greater
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-queue of timed events; FIFO among equal times.
pub struct EventQueue<T> {
    heap: BinaryHeap<Ev<T>>,
    seq: u64,
    /// Time of the last pop — popping is non-decreasing as long as pushes
    /// never schedule into the past (asserted in `push`).
    now: f64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Schedule `payload` at absolute simulated time `time` (ns). Must not
    /// be in the past of the last `pop` and must be finite — a NaN or
    /// retrograde event would silently corrupt the schedule, so both are
    /// hard errors.
    pub fn push(&mut self, time: f64, payload: T) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        assert!(
            time >= self.now,
            "event scheduled into the past: {time} < now {}",
            self.now
        );
        self.seq += 1;
        self.heap.push(Ev { time, seq: self.seq, payload });
    }

    /// Pop the earliest event; equal times come back in push order.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|ev| {
            self.now = ev.time;
            (ev.time, ev.payload)
        })
    }

    /// Simulated time of the last popped event.
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(5.0, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5.0, i)));
        }
    }

    /// Property (ISSUE 7 satellite 3a): for a fixed seed the pop order is
    /// bit-identical across runs — the event order is a pure function of
    /// the push sequence.
    #[test]
    fn pop_order_bit_identical_across_runs() {
        let run = |seed: u64| {
            let mut rng = Pcg32::new(seed, 17);
            let mut q = EventQueue::new();
            let mut order = Vec::new();
            // interleave pushes and pops, with deliberately colliding times
            for step in 0..500usize {
                let t = q.now() + (rng.below(8) as f64) * 0.5;
                q.push(t, step);
                if rng.below(3) == 0 {
                    if let Some((time, id)) = q.pop() {
                        order.push((time.to_bits(), id));
                    }
                }
            }
            while let Some((time, id)) = q.pop() {
                order.push((time.to_bits(), id));
            }
            order
        };
        assert_eq!(run(42), run(42));
        assert_eq!(run(1337), run(1337));
        assert_ne!(run(42), run(1337), "different seeds must differ");
    }

    /// Property: pop times are globally monotone non-decreasing (and hence
    /// monotone per component, whatever the payload partitioning).
    #[test]
    fn pop_times_monotone() {
        let mut rng = Pcg32::new(7, 3);
        let mut q = EventQueue::new();
        let mut last = 0.0f64;
        for i in 0..2000usize {
            q.push(q.now() + rng.uniform() * 10.0, i);
            if rng.below(2) == 0 {
                if let Some((t, _)) = q.pop() {
                    assert!(t >= last, "{t} < {last}");
                    last = t;
                }
            }
        }
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    #[should_panic(expected = "scheduled into the past")]
    fn retrograde_push_panics() {
        let mut q = EventQueue::new();
        q.push(10.0, ());
        q.pop();
        q.push(5.0, ());
    }
}
