//! S21: distributed AsySVRG — a discrete-event multi-node simulator with a
//! sharded parameter server and pluggable network cost models (DESIGN.md
//! §10).
//!
//! The single-box simulator (`simcore`) answers "what does p cores of one
//! machine cost?"; this module scales the question to *machines*: m nodes,
//! each running p local threads billed by the same calibrated
//! [`CostModel`]/[`UpdateBilling`](crate::simcore::UpdateBilling) path, a
//! parameter-server shard per node (shard k owns the coordinate range
//! `partition(d, m)[k]`), and a [`NetworkModel`] pricing every message as
//! latency + per-coordinate wire bytes (with an optional shared-throughput
//! mode for the epoch-boundary incast).
//!
//! **Event model.** Each epoch runs as a DAG of timed events on the
//! deterministic [`EventQueue`] (keyed `(time, seq)` — order is a pure
//! function of the seed):
//!
//! ```text
//! PullDone → GradDone → PartialArrived×m → ReduceDone → MuArrived×m
//!      (snapshot)  (local partial)   (shard merge)    (μ̄ broadcast)
//! MuArrived[all] → InnerDone  +  FlushArrived×F (update pushes)
//! ```
//!
//! Sync boundaries barrier every node on the global epoch end; async lets
//! each node proceed at its own finish using the freshest locally-available
//! μ̄ (the reduce/broadcast leave its critical path, at the price of extra
//! staleness, measured and reported as τ̂_net).
//!
//! **Parity contract.** At m = 1 there are no remote shards, so no network
//! events exist and the epoch is delegated to the shared single-box helper
//! [`sim_asysvrg_epoch`] — the m = 1 configuration reproduces
//! `simcore::sim_run` sim-seconds *bit-for-bit* (gated in CI, see
//! `tests/simdist_test.rs`).
//!
//! **Trajectory semantics.** Nodes sample uniformly from the shared corpus
//! (the paper's sampling model); each node's inner loop starts from the
//! epoch snapshot w and its delta is summed into the next iterate
//! (parameter-server delta application). The async boundary changes event
//! *timing* only — its convergence impact enters through the Theorem-1
//! feasibility check at the measured end-to-end τ̂, which includes the
//! network staleness window. Cross-epoch message interleavings are
//! approximated by a per-epoch event horizon with component clocks clamped
//! monotone.

pub mod net;
pub mod queue;

pub use net::{LatencyDist, NetworkModel};
pub use queue::EventQueue;

use crate::config::{Boundary, RunConfig, Storage};
use crate::coordinator::epoch::{parallel_full_grad, partition};
use crate::coordinator::monitor::HistoryPoint;
use crate::objective::Objective;
use crate::simcore::{
    full_grad_phase_ns, full_grad_phase_ns_range, sim_asysvrg_epoch, simulate_inner_opts,
    CostModel, EngineOpts, SimTask,
};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Cluster topology + boundary + network specification.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Machines m; shard k of the parameter vector lives on node k.
    pub nodes: usize,
    /// Local worker threads p per node (billed via the calibrated
    /// single-box cost model).
    pub threads_per_node: usize,
    /// Epoch-boundary discipline: global barrier vs free-running nodes.
    pub boundary: Boundary,
    pub net: NetworkModel,
    /// Update pushes to remote shards are batched into this many flushes
    /// per node per epoch (the last flush gates the node's epoch end).
    pub flushes_per_epoch: usize,
    /// Record a `(time, component)` event trace for the monotonicity
    /// property tests (components 0..m are nodes, m..2m shards).
    pub record_trace: bool,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            nodes: 1,
            threads_per_node: 4,
            boundary: Boundary::Sync,
            net: NetworkModel::zero(),
            flushes_per_epoch: 4,
            record_trace: false,
        }
    }
}

impl DistConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::Num(self.nodes as f64)),
            ("threads_per_node", Json::Num(self.threads_per_node as f64)),
            ("boundary", Json::Str(self.boundary.name().into())),
            ("net", self.net.to_json()),
            ("flushes_per_epoch", Json::Num(self.flushes_per_epoch as f64)),
        ])
    }
}

/// Outcome of one simulated cluster run.
#[derive(Clone, Debug, Default)]
pub struct DistResult {
    pub total_seconds: f64,
    pub epochs_run: usize,
    pub converged: bool,
    pub final_loss: f64,
    pub total_updates: u64,
    /// Worst within-node read→apply delay (the single-box τ̂).
    pub max_delay_node: u64,
    /// Worst measured network-staleness component: foreign updates landing
    /// at the parameter server inside one pull + push(+ stale-μ̄) window.
    pub tau_net: u64,
    /// End-to-end bounded delay fed to Theorem 1: within-node + network.
    pub tau_end_to_end: u64,
    /// Total simulated wire nanoseconds billed across the run.
    pub net_ns: f64,
    pub history: Vec<HistoryPoint>,
    /// `(time, component)` event log when `record_trace` is set.
    pub trace: Vec<(f64, usize)>,
}

impl DistResult {
    pub fn epochs_per_sec(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.epochs_run as f64 / self.total_seconds
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_seconds", Json::Num(self.total_seconds)),
            ("epochs_run", Json::Num(self.epochs_run as f64)),
            ("epochs_per_sec", Json::Num(self.epochs_per_sec())),
            ("converged", Json::Bool(self.converged)),
            ("final_loss", Json::Num(self.final_loss)),
            ("total_updates", Json::Num(self.total_updates as f64)),
            ("max_delay_node", Json::Num(self.max_delay_node as f64)),
            ("tau_net", Json::Num(self.tau_net as f64)),
            ("tau_end_to_end", Json::Num(self.tau_end_to_end as f64)),
            ("net_seconds", Json::Num(self.net_ns / 1e9)),
        ])
    }
}

/// Per-node inner seed: epoch t's single-box seed, decorrelated per node.
/// Node 0 uses the plain epoch seed so the m = 1 path is bit-identical to
/// `sim_asysvrg`.
fn node_seed(seed: u64, t: usize, k: usize) -> u64 {
    seed ^ ((t as u64) << 20) ^ ((k as u64) << 44)
}

/// Distinct-feature counts: corpus-wide and per node row-share — the
/// touched-coordinate payloads of the full-gradient reduce.
fn touched_counts(obj: &Objective, node_rows: &[std::ops::Range<usize>]) -> (usize, Vec<usize>) {
    let d = obj.dim();
    let mut global_seen = vec![false; d];
    let mut global = 0usize;
    let mut stamp = vec![usize::MAX; d];
    let mut per_node = Vec::with_capacity(node_rows.len());
    for (k, range) in node_rows.iter().enumerate() {
        let mut cnt = 0usize;
        for i in range.clone() {
            for &j in obj.data.row(i).indices {
                let j = j as usize;
                if stamp[j] != k {
                    stamp[j] = k;
                    cnt += 1;
                }
                if !global_seen[j] {
                    global_seen[j] = true;
                    global += 1;
                }
            }
        }
        per_node.push(cnt);
    }
    (global, per_node)
}

/// One epoch's cluster events (m > 1 only; m = 1 never constructs these).
#[derive(Clone, Copy, Debug)]
enum Ev {
    PullDone { node: usize },
    GradDone { node: usize },
    PartialArrived { shard: usize },
    ReduceDone { shard: usize },
    MuArrived { node: usize },
    InnerDone { node: usize },
    FlushArrived { node: usize, flush: usize, gen: f64 },
}

/// Static per-run cluster shape + wire payload sizes.
struct Cluster {
    m: usize,
    /// Snapshot coords node k must pull from remote shards: d − |shard k|.
    pull_coords: Vec<usize>,
    /// Remote share of node k's full-gradient partial (touched · (m−1)/m).
    partial_coords: Vec<usize>,
    /// Touched coords of node k's partial (sender-side pack cost).
    touched_node: Vec<usize>,
    /// μ̄ slice one shard broadcasts per recipient: touched_global / m.
    mu_coords: usize,
    /// Shard-side reduce entries: m partials × per-shard touched coords.
    reduce_entries: usize,
    /// Remote coords of one update-push flush from node k.
    flush_coords: Vec<usize>,
}

impl Cluster {
    fn new(
        obj: &Objective,
        cfg: &RunConfig,
        dist: &DistConfig,
        node_rows: &[std::ops::Range<usize>],
        updates_per_node: u64,
    ) -> Cluster {
        let m = dist.nodes;
        let d = obj.dim();
        let remote = (m - 1) as f64 / m as f64;
        let (touched_global, touched_node) = touched_counts(obj, node_rows);
        let shard_coords = partition(d, m);
        let pull_coords = (0..m).map(|k| d - shard_coords[k].len()).collect();
        let partial_coords =
            touched_node.iter().map(|&t| (t as f64 * remote).round() as usize).collect();
        let mu_coords = (touched_global as f64 / m as f64).ceil() as usize;
        let reduce_entries = m * mu_coords;
        let flushes = dist.flushes_per_epoch.max(1) as f64;
        let flush_coords = (0..m)
            .map(|_| {
                let batch = match cfg.storage {
                    Storage::Dense => d as f64,
                    Storage::Sparse => {
                        let per_flush = updates_per_node as f64 / flushes;
                        (per_flush * obj.data.avg_nnz()).min(touched_global as f64)
                    }
                };
                (batch * remote).round() as usize
            })
            .collect();
        Cluster {
            m,
            pull_coords,
            partial_coords,
            touched_node,
            mu_coords,
            reduce_entries,
            flush_coords,
        }
    }
}

/// Measured network-delay components of one epoch, per node.
struct EpochNet {
    pull_delay: Vec<f64>,
    push_delay_sum: Vec<f64>,
    push_count: Vec<usize>,
    mu_lag: Vec<f64>,
    start: f64,
    end: f64,
}

/// Run one epoch's cluster timeline on a fresh deterministic event queue.
/// `spans[k]` is node k's inner-loop simulated duration (from the engine).
/// Mutates node/shard clocks in place; returns the measured delays.
#[allow(clippy::too_many_arguments)]
fn epoch_timeline(
    cluster: &Cluster,
    dist: &DistConfig,
    costs: &CostModel,
    setup_ns: f64,
    grad_ns: &[f64],
    spans: &[f64],
    clocks: &mut [f64],
    shard_clocks: &mut [f64],
    rng: &mut Pcg32,
    net_ns: &mut f64,
    trace: &mut Vec<(f64, usize)>,
) -> EpochNet {
    let m = cluster.m;
    let sync = dist.boundary == Boundary::Sync;
    let flushes = dist.flushes_per_epoch.max(1);
    let mut q: EventQueue<Ev> = EventQueue::new();

    let mut pull_start = vec![0.0f64; m];
    let mut grad_done = vec![0.0f64; m];
    let mut inner_end = vec![0.0f64; m];
    let mut last_flush = vec![0.0f64; m];
    let mut partials = vec![0usize; m];
    let mut mus = vec![0usize; m];
    let mut stats = EpochNet {
        pull_delay: vec![0.0; m],
        push_delay_sum: vec![0.0; m],
        push_count: vec![0; m],
        mu_lag: vec![0.0; m],
        start: 0.0,
        end: 0.0,
    };

    // one transfer, burst concurrency = m aggregated per-node messages
    macro_rules! xfer {
        ($coords:expr) => {{
            let dur = dist.net.transfer_ns($coords, m, rng);
            *net_ns += dur;
            dur
        }};
    }
    // clamp a component clock monotone and record the trace point
    macro_rules! touch {
        ($clock:expr, $t:expr, $comp:expr) => {{
            let c: &mut f64 = &mut $clock;
            *c = c.max($t);
            if dist.record_trace {
                trace.push((*c, $comp));
            }
        }};
    }

    // epoch start: global barrier (sync) or each node's own clock (async)
    let barrier = clocks.iter().cloned().fold(0.0f64, f64::max);
    stats.start = if sync { barrier } else { clocks.iter().cloned().fold(f64::INFINITY, f64::min) };
    for k in 0..m {
        let s = if sync { barrier } else { clocks[k] };
        pull_start[k] = s + setup_ns;
        let dur = xfer!(cluster.pull_coords[k]);
        q.push(pull_start[k] + dur, Ev::PullDone { node: k });
    }

    // schedule one node's inner phase + its update-push flushes
    macro_rules! start_inner {
        ($k:expr, $t:expr, $q:expr) => {{
            let (k, t) = ($k, $t);
            $q.push(t + spans[k], Ev::InnerDone { node: k });
            for f in 1..=flushes {
                let gen = t + spans[k] * f as f64 / flushes as f64;
                let dur = costs.pack_cost(cluster.flush_coords[k]) + xfer!(cluster.flush_coords[k]);
                $q.push(gen + dur, Ev::FlushArrived { node: k, flush: f, gen });
            }
        }};
    }

    while let Some((t, ev)) = q.pop() {
        match ev {
            Ev::PullDone { node } => {
                stats.pull_delay[node] = t - pull_start[node];
                touch!(clocks[node], t, node);
                q.push(t + grad_ns[node], Ev::GradDone { node });
            }
            Ev::GradDone { node } => {
                grad_done[node] = t;
                touch!(clocks[node], t, node);
                // ship the partial to the remote shards (one aggregated
                // message; the own shard's slice arrives for free)
                let dur = costs.pack_cost(cluster.touched_node[node])
                    + xfer!(cluster.partial_coords[node]);
                for j in 0..m {
                    let at = if j == node { t } else { t + dur };
                    q.push(at, Ev::PartialArrived { shard: j });
                }
                if !sync {
                    // async boundary: don't wait for the reduce — run on
                    // the freshest locally-available μ̄
                    start_inner!(node, t, q);
                }
            }
            Ev::PartialArrived { shard } => {
                touch!(shard_clocks[shard], t, m + shard);
                partials[shard] += 1;
                if partials[shard] == m {
                    let merge = costs.epoch_merge_cost(cluster.reduce_entries);
                    q.push(t + merge, Ev::ReduceDone { shard });
                }
            }
            Ev::ReduceDone { shard } => {
                touch!(shard_clocks[shard], t, m + shard);
                for k in 0..m {
                    let at = if k == shard { t } else { t + xfer!(cluster.mu_coords) };
                    q.push(at, Ev::MuArrived { node: k });
                }
            }
            Ev::MuArrived { node } => {
                mus[node] += 1;
                if mus[node] == m {
                    stats.mu_lag[node] = (t - grad_done[node]).max(0.0);
                    if sync {
                        touch!(clocks[node], t, node);
                        start_inner!(node, t, q);
                    }
                }
            }
            Ev::InnerDone { node } => {
                inner_end[node] = t;
                touch!(clocks[node], t, node);
            }
            Ev::FlushArrived { node, flush, gen } => {
                stats.push_delay_sum[node] += t - gen;
                stats.push_count[node] += 1;
                for j in 0..m {
                    if j != node {
                        touch!(shard_clocks[j], t, m + j);
                    }
                }
                if flush == flushes {
                    last_flush[node] = t;
                }
            }
        }
    }

    // epoch end: a node is done when its inner loop finished AND its last
    // flush landed at the shards
    let mut global_end = 0.0f64;
    for k in 0..m {
        let end_k = inner_end[k].max(last_flush[k]);
        clocks[k] = clocks[k].max(end_k);
        global_end = global_end.max(end_k);
    }
    if sync {
        // the barrier: every node waits for the global epoch end
        for c in clocks.iter_mut() {
            *c = global_end;
        }
    }
    stats.end = global_end;
    stats
}

/// Simulate a full distributed AsySVRG run: m nodes × p threads against a
/// sharded parameter server over `dist.net`. See the module docs for the
/// event model and the m = 1 parity contract.
pub fn sim_dist_run(
    obj: &Objective,
    cfg: &RunConfig,
    dist: &DistConfig,
    costs: &CostModel,
    fstar: f64,
) -> DistResult {
    let m = dist.nodes;
    let p = dist.threads_per_node;
    assert!(m >= 1 && p >= 1, "need at least one node and one thread");
    let d = obj.dim();
    let n = obj.n();
    assert!(m <= n, "more nodes ({m}) than rows ({n})");

    // the trajectory is the p·m-way asynchronous run: per-thread inner
    // iterations shrink with the cluster so the per-epoch update budget
    // (m_factor·n) is machine-count-invariant — strong scaling
    let mut traj_cfg = cfg.clone();
    traj_cfg.threads = m * p;
    let m_per_thread = traj_cfg.inner_iters(n);
    let opts = EngineOpts { storage: cfg.storage, ..Default::default() };
    let setup_ns = costs.epoch_setup_cost(p, d, 2, opts.runtime);
    let passes_per_epoch = 1.0 + cfg.m_factor;

    let node_rows = partition(n, m);
    let grad_ns: Vec<f64> = node_rows
        .iter()
        .map(|r| full_grad_phase_ns_range(obj, r.clone(), p, costs, cfg.storage))
        .collect();
    let cluster = Cluster::new(obj, cfg, dist, &node_rows, (p * m_per_thread) as u64);
    let mut rng = Pcg32::new(cfg.seed ^ 0xD157_ED6E, 0xD157);

    let mut w = vec![0.0f32; d];
    let mut clocks = vec![0.0f64; m];
    let mut shard_clocks = vec![0.0f64; m];
    let mut result = DistResult::default();
    let mut passes = 0.0f64;
    let mut tau_net_max = 0.0f64;

    for t in 0..cfg.epochs {
        if m == 1 {
            // the parity fast path: no remote shards ⇒ no network events ⇒
            // the epoch IS the single-box epoch, billed by the shared
            // helper so timing and trajectory match sim_run bit-for-bit
            let (epoch_ns, r) = sim_asysvrg_epoch(
                obj,
                &traj_cfg,
                costs,
                &opts,
                full_grad_phase_ns(obj, p, costs, cfg.storage),
                setup_ns,
                t,
                &mut w,
            );
            clocks[0] += epoch_ns;
            if dist.record_trace {
                result.trace.push((clocks[0], 0));
            }
            result.max_delay_node = result.max_delay_node.max(r.max_delay);
            result.total_updates += r.updates;
        } else {
            // ---- math: every node runs its inner phase from the epoch
            // snapshot; deltas sum at the parameter server
            let eg = parallel_full_grad(obj, &w, 1);
            let u0 = w.clone();
            let task = SimTask::Svrg { u0: &u0, eg: &eg };
            let mut spans = Vec::with_capacity(m);
            let mut epoch_updates = Vec::with_capacity(m);
            let mut acc = w.clone();
            for k in 0..m {
                let mut u = w.clone();
                let r = simulate_inner_opts(
                    obj,
                    &task,
                    cfg.scheme,
                    costs,
                    &mut u,
                    cfg.eta,
                    p,
                    m_per_thread,
                    node_seed(cfg.seed, t, k),
                    &opts,
                );
                for j in 0..d {
                    acc[j] += u[j] - w[j];
                }
                spans.push(r.elapsed_ns);
                epoch_updates.push(r.updates);
                result.max_delay_node = result.max_delay_node.max(r.max_delay);
                result.total_updates += r.updates;
            }
            w = acc;

            // ---- timing: the cluster event timeline
            let stats = epoch_timeline(
                &cluster,
                dist,
                costs,
                setup_ns,
                &grad_ns,
                &spans,
                &mut clocks,
                &mut shard_clocks,
                &mut rng,
                &mut result.net_ns,
                &mut result.trace,
            );

            // ---- measured network staleness: foreign updates landing at
            // the parameter server inside one node's pull + mean-push
            // (+ stale-μ̄, async) window
            let wall = (stats.end - stats.start).max(1e-9);
            let total_upd: u64 = epoch_updates.iter().sum();
            for k in 0..m {
                let push_mean = if stats.push_count[k] > 0 {
                    stats.push_delay_sum[k] / stats.push_count[k] as f64
                } else {
                    0.0
                };
                let mut window = stats.pull_delay[k] + push_mean;
                if dist.boundary == Boundary::Async {
                    window += stats.mu_lag[k];
                }
                let foreign_rate = (total_upd - epoch_updates[k]) as f64 / wall;
                tau_net_max = tau_net_max.max((foreign_rate * window).ceil());
            }
        }

        let epoch_end = clocks.iter().cloned().fold(0.0f64, f64::max);
        passes += passes_per_epoch;
        let loss = obj.loss(&w);
        result.history.push(HistoryPoint {
            passes,
            loss,
            seconds: epoch_end / 1e9,
            updates: result.total_updates,
        });
        result.epochs_run = t + 1;
        if loss - fstar < cfg.target_gap {
            result.converged = true;
            break;
        }
    }

    result.total_seconds = clocks.iter().cloned().fold(0.0f64, f64::max) / 1e9;
    result.final_loss = obj.loss(&w);
    result.tau_net = tau_net_max as u64;
    result.tau_end_to_end = result.max_delay_node + result.tau_net;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;
    use crate::data::synthetic::SyntheticSpec;
    use std::sync::Arc;

    fn obj() -> Objective {
        let ds = SyntheticSpec::new("dist", 256, 64, 10, 13).generate();
        Objective::new(Arc::new(ds), 1e-2, crate::objective::LossKind::Logistic)
    }

    fn cfg() -> RunConfig {
        RunConfig {
            threads: 4,
            scheme: Scheme::Unlock,
            eta: 0.2,
            epochs: 3,
            target_gap: 0.0,
            storage: Storage::Sparse,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let o = obj();
        let costs = CostModel::default_host();
        let dist = DistConfig {
            nodes: 3,
            threads_per_node: 2,
            net: NetworkModel::lan(),
            ..Default::default()
        };
        let a = sim_dist_run(&o, &cfg(), &dist, &costs, f64::NEG_INFINITY);
        let b = sim_dist_run(&o, &cfg(), &dist, &costs, f64::NEG_INFINITY);
        assert_eq!(a.total_seconds.to_bits(), b.total_seconds.to_bits());
        assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
        assert_eq!(a.tau_end_to_end, b.tau_end_to_end);
        assert_eq!(a.net_ns.to_bits(), b.net_ns.to_bits());
    }

    #[test]
    fn seeds_change_the_run() {
        let o = obj();
        let costs = CostModel::default_host();
        let dist = DistConfig {
            nodes: 3,
            threads_per_node: 2,
            net: NetworkModel::lan(),
            ..Default::default()
        };
        let a = sim_dist_run(&o, &cfg(), &dist, &costs, f64::NEG_INFINITY);
        let mut c2 = cfg();
        c2.seed = 1337;
        let b = sim_dist_run(&o, &c2, &dist, &costs, f64::NEG_INFINITY);
        assert_ne!(a.final_loss.to_bits(), b.final_loss.to_bits());
    }

    #[test]
    fn converges_and_bills_network() {
        let o = obj();
        let costs = CostModel::default_host();
        let mut c = cfg();
        c.epochs = 8;
        let dist = DistConfig {
            nodes: 4,
            threads_per_node: 2,
            net: NetworkModel::lan(),
            ..Default::default()
        };
        let r = sim_dist_run(&o, &c, &dist, &costs, f64::NEG_INFINITY);
        assert_eq!(r.epochs_run, 8);
        assert!(r.final_loss < (2f64).ln(), "loss {}", r.final_loss);
        assert!(r.net_ns > 0.0, "a 4-node run must pay wire time");
        assert!(r.total_updates > 0);
        assert!(r.tau_end_to_end >= r.max_delay_node);
    }

    /// Per-component simulated clocks are monotone (ISSUE 7 satellite 3b):
    /// the traced event times never regress for any node or shard.
    #[test]
    fn component_clocks_monotone() {
        let o = obj();
        let costs = CostModel::default_host();
        for boundary in [Boundary::Sync, Boundary::Async] {
            let dist = DistConfig {
                nodes: 3,
                threads_per_node: 2,
                boundary,
                net: NetworkModel {
                    latency: LatencyDist::Exp { mean: 20_000.0 },
                    ..NetworkModel::lan()
                },
                record_trace: true,
                ..Default::default()
            };
            let r = sim_dist_run(&o, &cfg(), &dist, &costs, f64::NEG_INFINITY);
            assert!(!r.trace.is_empty());
            let mut last = vec![0.0f64; 2 * dist.nodes];
            for &(t, comp) in &r.trace {
                assert!(
                    t >= last[comp],
                    "{boundary:?}: component {comp} clock regressed: {t} < {}",
                    last[comp]
                );
                last[comp] = t;
            }
        }
    }

    /// Async boundaries never run slower than sync under latency: removing
    /// the barrier + reduce wait can only shorten the epoch.
    #[test]
    fn async_at_least_as_fast_as_sync_under_latency() {
        let o = obj();
        let costs = CostModel::default_host();
        let net = NetworkModel {
            latency: LatencyDist::Fixed(500_000.0), // 500 µs RPCs
            gbps: 1.0,
            shared: true,
            bytes_per_coord: 8.0,
        };
        let mk = |boundary| DistConfig {
            nodes: 4,
            threads_per_node: 2,
            boundary,
            net,
            ..Default::default()
        };
        let sync = sim_dist_run(&o, &cfg(), &mk(Boundary::Sync), &costs, f64::NEG_INFINITY);
        let asyn = sim_dist_run(&o, &cfg(), &mk(Boundary::Async), &costs, f64::NEG_INFINITY);
        assert!(
            asyn.total_seconds <= sync.total_seconds,
            "async {} !<= sync {}",
            asyn.total_seconds,
            sync.total_seconds
        );
        // the price of async: extra staleness through the stale-μ̄ window
        assert!(asyn.tau_end_to_end >= sync.tau_end_to_end.saturating_sub(1));
    }

    /// More latency ⇒ more simulated time and more network staleness.
    #[test]
    fn latency_costs_time_and_staleness() {
        let o = obj();
        let costs = CostModel::default_host();
        let mk = |lat_ns: f64| DistConfig {
            nodes: 4,
            threads_per_node: 2,
            net: NetworkModel {
                latency: if lat_ns == 0.0 { LatencyDist::Zero } else { LatencyDist::Fixed(lat_ns) },
                gbps: 10.0,
                shared: true,
                bytes_per_coord: 8.0,
            },
            ..Default::default()
        };
        let quiet = sim_dist_run(&o, &cfg(), &mk(0.0), &costs, f64::NEG_INFINITY);
        let slow = sim_dist_run(&o, &cfg(), &mk(2_000_000.0), &costs, f64::NEG_INFINITY);
        assert!(slow.total_seconds > quiet.total_seconds);
        assert!(slow.tau_net >= quiet.tau_net);
        // identical trajectory either way: the network changes timing only
        assert_eq!(slow.final_loss.to_bits(), quiet.final_loss.to_bits());
    }

    /// Zero-cost network, matched machine budget: distributing over more
    /// nodes must not slow the simulated run (the no-knee regime).
    #[test]
    fn free_network_scales_with_nodes() {
        let o = obj();
        let costs = CostModel::default_host();
        let mk = |m| DistConfig {
            nodes: m,
            threads_per_node: 2,
            net: NetworkModel::zero(),
            ..Default::default()
        };
        let t1 = sim_dist_run(&o, &cfg(), &mk(1), &costs, f64::NEG_INFINITY).total_seconds;
        let t4 = sim_dist_run(&o, &cfg(), &mk(4), &costs, f64::NEG_INFINITY).total_seconds;
        assert!(t4 < t1, "4 free nodes {t4} !< 1 node {t1}");
    }
}
