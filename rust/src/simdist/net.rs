//! Pluggable network cost model for the cluster simulator: a per-message
//! latency distribution plus per-link bandwidth, with an optional
//! shared-throughput mode where concurrent transfers split the link (the
//! epoch-boundary incast that dominates distributed ASGD at scale —
//! Keuper & Pfreundt, arXiv:1505.04956).
//!
//! All wire costs are billed **per touched coordinate**: a sparse update
//! push ships (index, value) pairs, so the payload of every message is
//! `coords · bytes_per_coord` bytes. Latency is sampled deterministically
//! from a seeded `Pcg32`, so a distributed run is a pure function of its
//! seed.

use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Per-message latency distribution (nanoseconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LatencyDist {
    /// No latency — the parity configuration (m=1 / same-box).
    Zero,
    /// Constant latency per message.
    Fixed(f64),
    /// Uniform in [lo, hi].
    Uniform { lo: f64, hi: f64 },
    /// Exponential with the given mean (heavy-ish tail: the occasional
    /// straggler RPC that sync barriers amplify).
    Exp { mean: f64 },
}

impl LatencyDist {
    /// Draw one latency sample (ns).
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        match *self {
            LatencyDist::Zero => 0.0,
            LatencyDist::Fixed(ns) => ns,
            LatencyDist::Uniform { lo, hi } => lo + (hi - lo) * rng.uniform(),
            LatencyDist::Exp { mean } => mean * rng.exponential(),
        }
    }

    /// Distribution mean (ns) — used for reporting, never for billing.
    pub fn mean_ns(&self) -> f64 {
        match *self {
            LatencyDist::Zero => 0.0,
            LatencyDist::Fixed(ns) => ns,
            LatencyDist::Uniform { lo, hi } => 0.5 * (lo + hi),
            LatencyDist::Exp { mean } => mean,
        }
    }

    /// Parse a CLI spec; times are **microseconds** (the natural unit for
    /// datacenter RPC): `zero`, `fixed:US`, `uniform:LO:HI`, `exp:MEAN`.
    pub fn parse(s: &str) -> Result<LatencyDist, String> {
        let us = 1_000.0; // µs → ns
        let parts: Vec<&str> = s.split(':').collect();
        // Reject bad magnitudes here, with the offending spec in the
        // message — not later, as an event-queue retrograde/non-finite
        // push panic deep inside the simulator. A latency is a duration:
        // finite and non-negative, no exceptions.
        let num = |x: &str| -> Result<f64, String> {
            let v = x
                .parse::<f64>()
                .map_err(|_| format!("bad latency number '{x}' in '{s}'"))?;
            if !v.is_finite() {
                return Err(format!("latency must be finite, got '{x}' in '{s}'"));
            }
            if v < 0.0 {
                return Err(format!("latency must be >= 0 µs, got '{x}' in '{s}'"));
            }
            Ok(v)
        };
        match parts.as_slice() {
            ["zero"] => Ok(LatencyDist::Zero),
            ["fixed", v] => Ok(LatencyDist::Fixed(num(v)? * us)),
            ["uniform", lo, hi] => {
                let (lo, hi) = (num(lo)? * us, num(hi)? * us);
                if hi < lo {
                    return Err(format!("uniform latency hi < lo in '{s}'"));
                }
                Ok(LatencyDist::Uniform { lo, hi })
            }
            ["exp", m] => Ok(LatencyDist::Exp { mean: num(m)? * us }),
            _ => Err(format!(
                "unknown latency spec '{s}' (zero|fixed:US|uniform:LO:HI|exp:MEAN — µs)"
            )),
        }
    }

    pub fn label(&self) -> String {
        let us = 1_000.0;
        match *self {
            LatencyDist::Zero => "zero".into(),
            LatencyDist::Fixed(ns) => format!("fixed:{}", ns / us),
            LatencyDist::Uniform { lo, hi } => format!("uniform:{}:{}", lo / us, hi / us),
            LatencyDist::Exp { mean } => format!("exp:{}", mean / us),
        }
    }
}

/// Latency + bandwidth model of one cluster interconnect.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetworkModel {
    pub latency: LatencyDist,
    /// Link bandwidth in gigabits/s; `f64::INFINITY` disables the
    /// serialization term entirely.
    pub gbps: f64,
    /// Shared-throughput option: `concurrent` simultaneous transfers each
    /// get `gbps / concurrent` (fluid fair-share, frozen at transfer
    /// start — the burst concurrency of an epoch-boundary incast).
    pub shared: bool,
    /// Wire bytes per parameter coordinate: u32 index + f32 value = 8.
    pub bytes_per_coord: f64,
}

impl NetworkModel {
    /// The parity configuration: zero latency, infinite bandwidth. Every
    /// transfer costs exactly 0.0 ns, so the m=1 cluster reproduces the
    /// single-box sim-seconds bit-for-bit.
    pub fn zero() -> Self {
        NetworkModel {
            latency: LatencyDist::Zero,
            gbps: f64::INFINITY,
            shared: false,
            bytes_per_coord: 8.0,
        }
    }

    /// A 10 GbE datacenter LAN: 50 µs fixed RPC latency, shared link.
    pub fn lan() -> Self {
        NetworkModel {
            latency: LatencyDist::Fixed(50_000.0),
            gbps: 10.0,
            shared: true,
            bytes_per_coord: 8.0,
        }
    }

    /// Duration (ns) of one `coords`-coordinate message when `concurrent`
    /// transfers share the link: one latency sample plus the serialization
    /// time of the payload at the (possibly split) bandwidth. 1 gbps =
    /// 1 bit/ns, so `bits / gbps` is already nanoseconds.
    pub fn transfer_ns(&self, coords: usize, concurrent: usize, rng: &mut Pcg32) -> f64 {
        let lat = self.latency.sample(rng);
        if coords == 0 {
            return lat;
        }
        let bits = coords as f64 * self.bytes_per_coord * 8.0;
        let eff = if self.shared { self.gbps / concurrent.max(1) as f64 } else { self.gbps };
        let wire = if eff.is_finite() && eff > 0.0 { bits / eff } else { 0.0 };
        lat + wire
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("latency", Json::Str(self.latency.label())),
            ("gbps", Json::Num(self.gbps)),
            ("shared", Json::Bool(self.shared)),
            ("bytes_per_coord", Json::Num(self.bytes_per_coord)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_network_costs_exactly_nothing() {
        let net = NetworkModel::zero();
        let mut rng = Pcg32::new(1, 1);
        for coords in [0usize, 1, 47_236] {
            for conc in [1usize, 4, 64] {
                assert_eq!(net.transfer_ns(coords, conc, &mut rng), 0.0);
            }
        }
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        for spec in ["zero", "fixed:50", "uniform:20:80", "exp:100"] {
            let d = LatencyDist::parse(spec).unwrap();
            assert_eq!(LatencyDist::parse(&d.label()).unwrap(), d, "{spec}");
        }
        assert_eq!(LatencyDist::parse("fixed:50").unwrap(), LatencyDist::Fixed(50_000.0));
        assert!(LatencyDist::parse("uniform:80:20").is_err());
        assert!(LatencyDist::parse("gaussian:5").is_err());
        assert!(LatencyDist::parse("fixed:abc").is_err());
        // Durations must be finite and non-negative *at parse time* —
        // previously these parsed fine and only blew up later as an
        // event-queue retrograde/non-finite push panic.
        for bad in [
            "fixed:-5",
            "fixed:inf",
            "fixed:nan",
            "exp:inf",
            "exp:-1",
            "exp:nan",
            "uniform:-10:50",
            "uniform:10:inf",
            "uniform:nan:50",
        ] {
            let err = LatencyDist::parse(bad).unwrap_err();
            assert!(
                err.contains(bad) || err.contains("latency"),
                "unhelpful error for '{bad}': {err}"
            );
        }
        // zero is a legal duration
        assert_eq!(LatencyDist::parse("fixed:0").unwrap(), LatencyDist::Fixed(0.0));
    }

    #[test]
    fn sampling_is_deterministic_and_in_support() {
        let d = LatencyDist::Uniform { lo: 1_000.0, hi: 2_000.0 };
        let mut a = Pcg32::new(9, 2);
        let mut b = Pcg32::new(9, 2);
        for _ in 0..100 {
            let x = d.sample(&mut a);
            assert_eq!(x, d.sample(&mut b));
            assert!((1_000.0..=2_000.0).contains(&x));
        }
        let e = LatencyDist::Exp { mean: 5_000.0 };
        let mut sum = 0.0;
        for _ in 0..5_000 {
            let x = e.sample(&mut a);
            assert!(x >= 0.0);
            sum += x;
        }
        assert!((sum / 5_000.0 - 5_000.0).abs() < 500.0, "exp mean off: {}", sum / 5_000.0);
    }

    #[test]
    fn shared_link_splits_bandwidth() {
        let net = NetworkModel { latency: LatencyDist::Zero, ..NetworkModel::lan() };
        let mut rng = Pcg32::new(1, 1);
        let one = net.transfer_ns(10_000, 1, &mut rng);
        let four = net.transfer_ns(10_000, 4, &mut rng);
        assert!((four - 4.0 * one).abs() < 1e-9, "fair share: {four} vs 4×{one}");
        // dedicated links ignore concurrency
        let ded = NetworkModel { shared: false, ..net };
        assert_eq!(ded.transfer_ns(10_000, 1, &mut rng), ded.transfer_ns(10_000, 4, &mut rng));
        // 10_000 coords × 8 B × 8 b / 10 gbps = 64 µs
        assert!((one - 64_000.0).abs() < 1e-6, "wire time {one}");
    }

    #[test]
    fn latency_applies_even_to_empty_messages() {
        let net = NetworkModel { latency: LatencyDist::Fixed(7_000.0), ..NetworkModel::lan() };
        let mut rng = Pcg32::new(1, 1);
        assert_eq!(net.transfer_ns(0, 8, &mut rng), 7_000.0);
    }
}
