//! Command-line parsing substrate (no clap in the vendor set).
//!
//! Model: `repro <subcommand> [--flag] [--key value]...`. Flags are
//! declared up front so `--help` is generated and unknown arguments are
//! hard errors (silent typos in experiment parameters are how wrong tables
//! get published).

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Declarative command spec.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, takes_value: false, default: None, help });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, takes_value: true, default: Some(default), help });
        self
    }

    /// Required option (no default).
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, takes_value: true, default: None, help });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let v = if o.takes_value { " <value>" } else { "" };
            let d = match o.default {
                Some(d) => format!(" [default: {d}]"),
                None if o.takes_value => " [required]".to_string(),
                None => String::new(),
            };
            s.push_str(&format!("  --{}{v}\n      {}{d}\n", o.name, o.help));
        }
        s
    }

    /// Parse `args` (not including the subcommand itself).
    pub fn parse(&self, args: &[String]) -> Result<Matches, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            let name = a
                .strip_prefix("--")
                .ok_or_else(|| format!("unexpected positional argument '{a}'\n\n{}", self.usage()))?;
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let opt = self
                .opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| format!("unknown option '--{name}'\n\n{}", self.usage()))?;
            if opt.takes_value {
                let v = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| format!("option '--{name}' needs a value"))?
                    }
                };
                values.insert(name.to_string(), v);
            } else {
                if inline.is_some() {
                    return Err(format!("flag '--{name}' takes no value"));
                }
                flags.push(name.to_string());
            }
            i += 1;
        }
        // defaults + required checks
        for o in &self.opts {
            if o.takes_value && !values.contains_key(o.name) {
                match o.default {
                    Some(d) => {
                        values.insert(o.name.to_string(), d.to_string());
                    }
                    None => return Err(format!("missing required option '--{}'", o.name)),
                }
            }
        }
        Ok(Matches { values, flags })
    }
}

/// Parsed option values with typed accessors.
#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Matches {
    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| {
            panic!("option '{name}' not declared (internal bug)");
        })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.str(name).parse().map_err(|_| format!("--{name}: expected integer, got '{}'", self.str(name)))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.str(name).parse().map_err(|_| format!("--{name}: expected integer, got '{}'", self.str(name)))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.str(name).parse().map_err(|_| format!("--{name}: expected number, got '{}'", self.str(name)))
    }

    pub fn f32(&self, name: &str) -> Result<f32, String> {
        self.str(name).parse().map_err(|_| format!("--{name}: expected number, got '{}'", self.str(name)))
    }

    /// Strictly positive finite f64 — for rates, factors, and budgets where
    /// `-5`, `0`, `inf`, or `nan` would surface much later as a panic or a
    /// silently degenerate run.
    pub fn f64_pos(&self, name: &str) -> Result<f64, String> {
        let v = self.f64(name)?;
        if !v.is_finite() || v <= 0.0 {
            return Err(format!(
                "--{name}: expected a positive finite number, got '{}'",
                self.str(name)
            ));
        }
        Ok(v)
    }

    /// Strictly positive integer — for counts (batch widths, thread pools)
    /// where `0`, `-3`, or `2.5` must fail at parse time, not later as a
    /// modulo-by-zero panic or a silently empty run.
    pub fn usize_pos(&self, name: &str) -> Result<usize, String> {
        let v = self.usize(name)?;
        if v == 0 {
            return Err(format!(
                "--{name}: expected a positive integer, got '{}'",
                self.str(name)
            ));
        }
        Ok(v)
    }

    /// Comma-separated usize list, e.g. `--threads 1,2,4,8,10`.
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, String> {
        self.str(name)
            .split(',')
            .map(|t| t.trim().parse().map_err(|_| format!("--{name}: bad list item '{t}'")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "run an experiment")
            .opt("dataset", "rcv1", "dataset name")
            .opt("threads", "10", "thread count")
            .req("eta", "step size")
            .flag("verbose", "chatty output")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let m = cmd().parse(&args(&["--eta", "0.1", "--threads=4"])).unwrap();
        assert_eq!(m.str("dataset"), "rcv1");
        assert_eq!(m.usize("threads").unwrap(), 4);
        assert_eq!(m.f64("eta").unwrap(), 0.1);
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn flags() {
        let m = cmd().parse(&args(&["--eta", "0.1", "--verbose"])).unwrap();
        assert!(m.flag("verbose"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(cmd().parse(&args(&[])).unwrap_err().contains("eta"));
    }

    #[test]
    fn unknown_option_rejected() {
        let e = cmd().parse(&args(&["--eta", "0.1", "--bogus", "1"])).unwrap_err();
        assert!(e.contains("unknown option"));
    }

    #[test]
    fn value_missing_rejected() {
        let e = cmd().parse(&args(&["--eta"])).unwrap_err();
        assert!(e.contains("needs a value"));
    }

    #[test]
    fn positive_finite_numbers() {
        let c = Command::new("x", "y").opt("qps", "100", "rate");
        for (val, ok) in
            [("100", true), ("0.5", true), ("0", false), ("-5", false), ("inf", false), ("nan", false)]
        {
            let m = c.parse(&args(&["--qps", val])).unwrap();
            assert_eq!(m.f64_pos("qps").is_ok(), ok, "--qps {val}");
        }
    }

    #[test]
    fn positive_integers() {
        let c = Command::new("x", "y").opt("batch", "1", "width");
        for (val, ok) in [("1", true), ("8", true), ("0", false), ("-2", false), ("2.5", false)] {
            let m = c.parse(&args(&["--batch", val])).unwrap();
            assert_eq!(m.usize_pos("batch").is_ok(), ok, "--batch {val}");
        }
    }

    #[test]
    fn lists() {
        let c = Command::new("x", "y").opt("threads", "1,2,4", "list");
        let m = c.parse(&args(&[])).unwrap();
        assert_eq!(m.usize_list("threads").unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let e = cmd().parse(&args(&["--help"])).unwrap_err();
        assert!(e.contains("--dataset") && e.contains("required"));
    }
}
