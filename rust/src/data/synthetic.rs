//! Synthetic dataset generation.
//!
//! The host has no network access to the LibSVM site, so the paper's three
//! corpora are stood in for by generators matched to Table 1 statistics
//! (n, d, avg nnz/row) with a planted linear separator + label noise — the
//! substitution is documented in DESIGN.md §2. A two-tier feature-popularity
//! mixture (head features much hotter than tail) mimics the Zipfian token
//! distribution of the real text corpora, which matters for the async
//! schemes: hot coordinates are where lock-free updates collide.

use super::dataset::Dataset;
use crate::util::rng::Pcg32;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: String,
    pub n: usize,
    pub dim: usize,
    /// Mean non-zeros per row (actual count varies ±50%).
    pub avg_nnz: usize,
    /// Probability that a label is flipped after the planted rule.
    pub label_noise: f64,
    /// Fraction of nnz drawn from the hot head (√d features).
    pub head_mass: f64,
    pub seed: u64,
}

impl SyntheticSpec {
    pub fn new(name: &str, n: usize, dim: usize, avg_nnz: usize, seed: u64) -> Self {
        SyntheticSpec {
            name: name.to_string(),
            n,
            dim,
            avg_nnz,
            label_noise: 0.05,
            head_mass: 0.5,
            seed,
        }
    }

    /// Generate the dataset (rows L2-normalized, labels ±1 balanced-ish).
    pub fn generate(&self) -> Dataset {
        assert!(self.avg_nnz >= 1 && self.avg_nnz <= self.dim);
        let mut rng = Pcg32::new(self.seed, 0xDA7A);
        // planted separator over the head features (tail contributes noise)
        let head = (self.dim as f64).sqrt().ceil() as usize;
        let head = head.clamp(1, self.dim);
        let wstar: Vec<f32> = (0..self.dim)
            .map(|j| {
                let base = rng.gaussian() as f32;
                if j < head {
                    base
                } else {
                    base * 0.1
                }
            })
            .collect();

        let mut rows = Vec::with_capacity(self.n);
        let mut labels = Vec::with_capacity(self.n);
        let mut scratch: Vec<u32> = Vec::new();
        for _ in 0..self.n {
            // row size: uniform in [avg/2, 3*avg/2], clamped to [1, dim]
            let lo = (self.avg_nnz / 2).max(1);
            let hi = (self.avg_nnz * 3 / 2).max(lo + 1).min(self.dim);
            let k = lo + rng.below(hi - lo + 1);
            scratch.clear();
            while scratch.len() < k {
                let j = if rng.uniform() < self.head_mass {
                    rng.below(head) as u32
                } else {
                    rng.below(self.dim) as u32
                };
                // insertion keeping sorted-unique; k is small (≲ 1000)
                match scratch.binary_search(&j) {
                    Ok(_) => continue,
                    Err(pos) => scratch.insert(pos, j),
                }
            }
            let mut vals: Vec<f32> = (0..k).map(|_| rng.gaussian().abs() as f32 + 0.1).collect();
            // L2-normalize the row at generation time
            let sq: f32 = vals.iter().map(|v| v * v).sum();
            let inv = 1.0 / sq.sqrt();
            for v in &mut vals {
                *v *= inv;
            }
            // label from the planted rule + noise
            let mut margin = 0.0f32;
            for (pos, &j) in scratch.iter().enumerate() {
                margin += vals[pos] * wstar[j as usize];
            }
            let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
            if rng.uniform() < self.label_noise {
                y = -y;
            }
            rows.push((scratch.clone(), vals));
            labels.push(y);
        }
        Dataset::from_rows(rows, labels, self.dim, &self.name).expect("generator invariants")
    }
}

/// The paper's three corpora (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PaperDataset {
    Rcv1,
    RealSim,
    News20,
}

impl PaperDataset {
    pub fn name(&self) -> &'static str {
        match self {
            PaperDataset::Rcv1 => "rcv1",
            PaperDataset::RealSim => "real-sim",
            PaperDataset::News20 => "news20",
        }
    }

    /// Table 1 statistics: (n, d, avg nnz/row) of the LibSVM files.
    pub fn stats(&self) -> (usize, usize, usize) {
        match self {
            PaperDataset::Rcv1 => (20_242, 47_236, 74),
            PaperDataset::RealSim => (72_309, 20_958, 52),
            PaperDataset::News20 => (19_996, 1_355_191, 455),
        }
    }

    /// The paper's λ (same for all three datasets).
    pub fn lambda(&self) -> f32 {
        1e-4
    }

    pub fn all() -> [PaperDataset; 3] {
        [PaperDataset::Rcv1, PaperDataset::RealSim, PaperDataset::News20]
    }
}

/// Synthetic stand-in for a paper dataset, optionally scaled down.
/// `scale` ∈ (0, 1] multiplies n and d (dense update cost is O(d) per inner
/// step, so full-size news20 runs are gated behind --full; see DESIGN.md).
pub fn paper_dataset(which: PaperDataset, scale: f64, seed: u64) -> Dataset {
    assert!(scale > 0.0 && scale <= 1.0);
    let (n, d, nnz) = which.stats();
    let n = ((n as f64 * scale) as usize).max(64);
    let d = ((d as f64 * scale) as usize).max(16);
    let nnz = nnz.min(d);
    let name = if scale == 1.0 {
        format!("{}-synth", which.name())
    } else {
        format!("{}-synth@{scale}", which.name())
    };
    SyntheticSpec::new(&name, n, d, nnz, seed).generate()
}

/// Small dense dataset (every feature present in every row) for unit tests
/// and the XLA dense-path e2e driver — its dim must match the AOT manifest.
pub fn small_dense(n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0xDEBE);
    let wstar: Vec<f32> = (0..dim).map(|_| rng.gaussian() as f32).collect();
    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let mut vals: Vec<f32> = (0..dim).map(|_| rng.gaussian() as f32).collect();
        let sq: f32 = vals.iter().map(|v| v * v).sum();
        let inv = 1.0 / sq.sqrt();
        for v in &mut vals {
            *v *= inv;
        }
        let margin: f32 = vals.iter().zip(&wstar).map(|(a, b)| a * b).sum();
        let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.uniform() < 0.02 {
            y = -y;
        }
        rows.push(((0..dim as u32).collect(), vals));
        labels.push(y);
    }
    Dataset::from_rows(rows, labels, dim, &format!("dense{n}x{dim}")).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_matches_spec() {
        let ds = SyntheticSpec::new("t", 500, 1000, 20, 7).generate();
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.dim, 1000);
        let avg = ds.nnz() as f64 / ds.n() as f64;
        assert!((10.0..=30.0).contains(&avg), "avg nnz {avg}");
        // rows normalized
        assert!((ds.max_row_sq_norm() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SyntheticSpec::new("t", 100, 200, 10, 3).generate();
        let b = SyntheticSpec::new("t", 100, 200, 10, 3).generate();
        assert_eq!(a.values, b.values);
        assert_eq!(a.labels, b.labels);
        let c = SyntheticSpec::new("t", 100, 200, 10, 4).generate();
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn labels_roughly_balanced_and_learnable() {
        let ds = SyntheticSpec::new("t", 2000, 500, 15, 11).generate();
        let pos = ds.labels.iter().filter(|&&y| y > 0.0).count();
        let frac = pos as f64 / ds.n() as f64;
        assert!((0.25..=0.75).contains(&frac), "pos frac {frac}");
    }

    #[test]
    fn paper_scaled_stats() {
        let ds = paper_dataset(PaperDataset::Rcv1, 0.05, 1);
        assert_eq!(ds.n(), (20_242.0f64 * 0.05) as usize);
        assert_eq!(ds.dim, (47_236.0f64 * 0.05) as usize);
        let avg = ds.nnz() as f64 / ds.n() as f64;
        assert!((37.0..=111.0).contains(&avg), "avg {avg}");
    }

    #[test]
    fn small_dense_is_dense() {
        let ds = small_dense(32, 16, 5);
        assert_eq!(ds.nnz(), 32 * 16);
        assert!((ds.max_row_sq_norm() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn table1_constants() {
        assert_eq!(PaperDataset::Rcv1.stats().0, 20_242);
        assert_eq!(PaperDataset::News20.stats().1, 1_355_191);
        assert_eq!(PaperDataset::RealSim.lambda(), 1e-4);
    }
}
