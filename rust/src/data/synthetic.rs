//! Synthetic dataset generation.
//!
//! The host has no network access to the LibSVM site, so the paper's three
//! corpora are stood in for by generators matched to Table 1 statistics
//! (n, d, avg nnz/row) with a planted linear separator + label noise — the
//! substitution is documented in DESIGN.md §2. A two-tier feature-popularity
//! mixture (head features much hotter than tail) mimics the Zipfian token
//! distribution of the real text corpora, which matters for the async
//! schemes: hot coordinates are where lock-free updates collide.
//!
//! For contention work the two-tier mixture is too blunt: the collision
//! rate of a lock-free write set is driven by the full shape of the
//! feature-popularity tail, not just its head mass. `SyntheticSpec`
//! therefore carries an optional **power-law axis** (`with_zipf`): feature
//! j is drawn with probability ∝ 1/(j+1)^s, the classic Zipf form whose
//! exponent s sweeps continuously from uniform (s = 0) to brutally
//! head-heavy (s ≥ 1.5). The resulting `Dataset::coord_touch_concentration`
//! is monotone in s, which is exactly the knob the contention calibration
//! (`repro calibrate --contention`, DESIGN.md §6) sweeps.

use super::dataset::Dataset;
use crate::util::rng::Pcg32;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: String,
    pub n: usize,
    pub dim: usize,
    /// Mean non-zeros per row (actual count varies ±50%).
    pub avg_nnz: usize,
    /// Probability that a label is flipped after the planted rule.
    pub label_noise: f64,
    /// Fraction of nnz drawn from the hot head (√d features). Ignored when
    /// `zipf_exponent` is set — the power law then fixes the head mass.
    pub head_mass: f64,
    /// Power-law feature popularity: feature j drawn ∝ 1/(j+1)^s. `None`
    /// keeps the legacy two-tier head/tail mixture.
    pub zipf_exponent: Option<f64>,
    pub seed: u64,
}

impl SyntheticSpec {
    pub fn new(name: &str, n: usize, dim: usize, avg_nnz: usize, seed: u64) -> Self {
        SyntheticSpec {
            name: name.to_string(),
            n,
            dim,
            avg_nnz,
            label_noise: 0.05,
            head_mass: 0.5,
            zipf_exponent: None,
            seed,
        }
    }

    /// Switch feature popularity to a pure power law with exponent `s ≥ 0`
    /// (0 = uniform). Exponents much above ~2 make distinct-coordinate rows
    /// expensive to draw on small dims; the generator falls back to rank
    /// order to stay O(nnz)-ish and deterministic.
    pub fn with_zipf(mut self, s: f64) -> Self {
        assert!(s >= 0.0 && s.is_finite(), "zipf exponent must be finite and >= 0");
        self.zipf_exponent = Some(s);
        self
    }

    /// Mass of the top-√d features under this spec's popularity law — the
    /// diagnostic matching the two-tier `head_mass` knob.
    pub fn head_mass_of(&self) -> f64 {
        let head = ((self.dim as f64).sqrt().ceil() as usize).clamp(1, self.dim);
        match self.zipf_exponent {
            None => self.head_mass + (1.0 - self.head_mass) * head as f64 / self.dim as f64,
            Some(s) => {
                let w = |j: usize| 1.0 / ((j + 1) as f64).powf(s);
                let head_w: f64 = (0..head).map(w).sum();
                let total_w: f64 = (0..self.dim).map(w).sum();
                head_w / total_w
            }
        }
    }

    /// Generate the dataset (rows L2-normalized, labels ±1 balanced-ish).
    pub fn generate(&self) -> Dataset {
        assert!(self.avg_nnz >= 1 && self.avg_nnz <= self.dim);
        let mut rng = Pcg32::new(self.seed, 0xDA7A);
        // planted separator over the head features (tail contributes noise)
        let head = (self.dim as f64).sqrt().ceil() as usize;
        let head = head.clamp(1, self.dim);
        let wstar: Vec<f32> = (0..self.dim)
            .map(|j| {
                let base = rng.gaussian() as f32;
                if j < head {
                    base
                } else {
                    base * 0.1
                }
            })
            .collect();

        // power-law mode: cumulative weights once, inverse-CDF per draw
        let zipf_cum: Option<Vec<f64>> = self.zipf_exponent.map(|s| {
            let mut acc = 0.0f64;
            (0..self.dim)
                .map(|j| {
                    acc += 1.0 / ((j + 1) as f64).powf(s);
                    acc
                })
                .collect()
        });

        let mut rows = Vec::with_capacity(self.n);
        let mut labels = Vec::with_capacity(self.n);
        let mut scratch: Vec<u32> = Vec::new();
        for _ in 0..self.n {
            // row size: uniform in [avg/2, 3*avg/2], clamped to [1, dim]
            let lo = (self.avg_nnz / 2).max(1);
            let hi = (self.avg_nnz * 3 / 2).max(lo + 1).min(self.dim);
            let k = lo + rng.below(hi - lo + 1);
            scratch.clear();
            let mut attempts = 0usize;
            while scratch.len() < k {
                // a steep power law on a small dim makes distinct draws
                // rejection-heavy; past the attempt budget, fill the rest
                // deterministically with the hottest unused ranks
                attempts += 1;
                if attempts > 200 * k {
                    let mut j = 0u32;
                    while scratch.len() < k {
                        if let Err(pos) = scratch.binary_search(&j) {
                            scratch.insert(pos, j);
                        }
                        j += 1;
                    }
                    break;
                }
                let j = match &zipf_cum {
                    Some(cum) => {
                        let u = rng.uniform() * cum[self.dim - 1];
                        (cum.partition_point(|&c| c < u).min(self.dim - 1)) as u32
                    }
                    None if rng.uniform() < self.head_mass => rng.below(head) as u32,
                    None => rng.below(self.dim) as u32,
                };
                // insertion keeping sorted-unique; k is small (≲ 1000)
                match scratch.binary_search(&j) {
                    Ok(_) => continue,
                    Err(pos) => scratch.insert(pos, j),
                }
            }
            let mut vals: Vec<f32> = (0..k).map(|_| rng.gaussian().abs() as f32 + 0.1).collect();
            // L2-normalize the row at generation time
            let sq: f32 = vals.iter().map(|v| v * v).sum();
            let inv = 1.0 / sq.sqrt();
            for v in &mut vals {
                *v *= inv;
            }
            // label from the planted rule + noise
            let mut margin = 0.0f32;
            for (pos, &j) in scratch.iter().enumerate() {
                margin += vals[pos] * wstar[j as usize];
            }
            let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
            if rng.uniform() < self.label_noise {
                y = -y;
            }
            rows.push((scratch.clone(), vals));
            labels.push(y);
        }
        Dataset::from_rows(rows, labels, self.dim, &self.name).expect("generator invariants")
    }
}

/// The paper's three corpora (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PaperDataset {
    Rcv1,
    RealSim,
    News20,
}

impl PaperDataset {
    pub fn name(&self) -> &'static str {
        match self {
            PaperDataset::Rcv1 => "rcv1",
            PaperDataset::RealSim => "real-sim",
            PaperDataset::News20 => "news20",
        }
    }

    /// Table 1 statistics: (n, d, avg nnz/row) of the LibSVM files.
    pub fn stats(&self) -> (usize, usize, usize) {
        match self {
            PaperDataset::Rcv1 => (20_242, 47_236, 74),
            PaperDataset::RealSim => (72_309, 20_958, 52),
            PaperDataset::News20 => (19_996, 1_355_191, 455),
        }
    }

    /// The paper's λ (same for all three datasets).
    pub fn lambda(&self) -> f32 {
        1e-4
    }

    pub fn all() -> [PaperDataset; 3] {
        [PaperDataset::Rcv1, PaperDataset::RealSim, PaperDataset::News20]
    }
}

/// Synthetic stand-in for a paper dataset, optionally scaled down.
/// `scale` ∈ (0, 1] multiplies n and d (dense update cost is O(d) per inner
/// step, so full-size news20 runs are gated behind --full; see DESIGN.md).
pub fn paper_dataset(which: PaperDataset, scale: f64, seed: u64) -> Dataset {
    assert!(scale > 0.0 && scale <= 1.0);
    let (n, d, nnz) = which.stats();
    let n = ((n as f64 * scale) as usize).max(64);
    let d = ((d as f64 * scale) as usize).max(16);
    let nnz = nnz.min(d);
    let name = if scale == 1.0 {
        format!("{}-synth", which.name())
    } else {
        format!("{}-synth@{scale}", which.name())
    };
    SyntheticSpec::new(&name, n, d, nnz, seed).generate()
}

/// Zipfian contended-update scenario (DESIGN.md §6): rcv1-shaped sizes at
/// `scale` with power-law feature popularity of exponent `s`. This is the
/// workload the contention calibration and the `BENCH_contention.json`
/// smoke run on — hot-head collisions are the point, not an artifact.
pub fn zipf_scenario(s: f64, scale: f64, seed: u64) -> Dataset {
    assert!(scale > 0.0 && scale <= 1.0);
    let (n, d, nnz) = PaperDataset::Rcv1.stats();
    let n = ((n as f64 * scale) as usize).max(64);
    let d = ((d as f64 * scale) as usize).max(16);
    let nnz = nnz.min(d);
    SyntheticSpec::new(&format!("zipf{s}@{scale}"), n, d, nnz, seed)
        .with_zipf(s)
        .generate()
}

/// Small dense dataset (every feature present in every row) for unit tests
/// and the XLA dense-path e2e driver — its dim must match the AOT manifest.
pub fn small_dense(n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0xDEBE);
    let wstar: Vec<f32> = (0..dim).map(|_| rng.gaussian() as f32).collect();
    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let mut vals: Vec<f32> = (0..dim).map(|_| rng.gaussian() as f32).collect();
        let sq: f32 = vals.iter().map(|v| v * v).sum();
        let inv = 1.0 / sq.sqrt();
        for v in &mut vals {
            *v *= inv;
        }
        let margin: f32 = vals.iter().zip(&wstar).map(|(a, b)| a * b).sum();
        let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.uniform() < 0.02 {
            y = -y;
        }
        rows.push(((0..dim as u32).collect(), vals));
        labels.push(y);
    }
    Dataset::from_rows(rows, labels, dim, &format!("dense{n}x{dim}")).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_matches_spec() {
        let ds = SyntheticSpec::new("t", 500, 1000, 20, 7).generate();
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.dim, 1000);
        let avg = ds.nnz() as f64 / ds.n() as f64;
        assert!((10.0..=30.0).contains(&avg), "avg nnz {avg}");
        // rows normalized
        assert!((ds.max_row_sq_norm() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SyntheticSpec::new("t", 100, 200, 10, 3).generate();
        let b = SyntheticSpec::new("t", 100, 200, 10, 3).generate();
        assert_eq!(a.values, b.values);
        assert_eq!(a.labels, b.labels);
        let c = SyntheticSpec::new("t", 100, 200, 10, 4).generate();
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn labels_roughly_balanced_and_learnable() {
        let ds = SyntheticSpec::new("t", 2000, 500, 15, 11).generate();
        let pos = ds.labels.iter().filter(|&&y| y > 0.0).count();
        let frac = pos as f64 / ds.n() as f64;
        assert!((0.25..=0.75).contains(&frac), "pos frac {frac}");
    }

    #[test]
    fn paper_scaled_stats() {
        let ds = paper_dataset(PaperDataset::Rcv1, 0.05, 1);
        assert_eq!(ds.n(), (20_242.0f64 * 0.05) as usize);
        assert_eq!(ds.dim, (47_236.0f64 * 0.05) as usize);
        let avg = ds.nnz() as f64 / ds.n() as f64;
        assert!((37.0..=111.0).contains(&avg), "avg {avg}");
    }

    #[test]
    fn zipf_generator_matches_spec_and_is_deterministic() {
        let spec = SyntheticSpec::new("z", 400, 1000, 20, 7).with_zipf(1.1);
        let a = spec.generate();
        assert_eq!(a.n(), 400);
        assert_eq!(a.dim, 1000);
        let avg = a.avg_nnz();
        assert!((10.0..=30.0).contains(&avg), "avg nnz {avg}");
        assert!((a.max_row_sq_norm() - 1.0).abs() < 1e-4);
        let b = SyntheticSpec::new("z", 400, 1000, 20, 7).with_zipf(1.1).generate();
        assert_eq!(a.values, b.values);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn zipf_exponent_raises_touch_concentration_monotonically() {
        // the contention model's skew axis: steeper exponent ⇒ hotter head
        let conc = |s: f64| {
            SyntheticSpec::new("z", 600, 2000, 15, 7)
                .with_zipf(s)
                .generate()
                .coord_touch_concentration()
        };
        let uniform = conc(0.0);
        let mild = conc(0.8);
        let steep = conc(1.6);
        assert!(uniform < mild && mild < steep, "{uniform} !< {mild} !< {steep}");
        // s = 0 is near the uniform floor 1/d (row-size jitter keeps it loose)
        assert!(uniform < 5.0 / 2000.0, "uniform concentration {uniform}");
        // the steep head concentrates two orders of magnitude harder
        assert!(steep > 20.0 * uniform, "steep {steep} vs uniform {uniform}");
    }

    #[test]
    fn zipf_head_mass_diagnostic_tracks_exponent() {
        let spec = |s| SyntheticSpec::new("z", 100, 10_000, 10, 3).with_zipf(s);
        assert!(spec(0.0).head_mass_of() < 0.05); // √d/d = 1%ish
        let hm = spec(1.2).head_mass_of();
        assert!(hm > 0.4, "s=1.2 head mass {hm}");
        assert!(spec(1.2).head_mass_of() < spec(1.8).head_mass_of());
    }

    #[test]
    fn zipf_steep_exponent_still_generates_valid_rows() {
        // steep law on a tiny dim exercises the deterministic fallback fill
        let ds = SyntheticSpec::new("z", 50, 12, 8, 9).with_zipf(3.0).generate();
        assert_eq!(ds.n(), 50);
        for i in 0..ds.n() {
            assert!(ds.row(i).nnz() >= 1);
        }
        assert!((ds.max_row_sq_norm() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn zipf_scenario_shapes_like_rcv1() {
        let ds = zipf_scenario(1.1, 0.02, 5);
        assert_eq!(ds.n(), (20_242.0f64 * 0.02) as usize);
        assert_eq!(ds.dim, (47_236.0f64 * 0.02) as usize);
        assert!(ds.name.starts_with("zipf1.1@"));
    }

    #[test]
    fn small_dense_is_dense() {
        let ds = small_dense(32, 16, 5);
        assert_eq!(ds.nnz(), 32 * 16);
        assert!((ds.max_row_sq_norm() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn table1_constants() {
        assert_eq!(PaperDataset::Rcv1.stats().0, 20_242);
        assert_eq!(PaperDataset::News20.stats().1, 1_355_191);
        assert_eq!(PaperDataset::RealSim.lambda(), 1e-4);
    }
}
