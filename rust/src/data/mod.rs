//! Dataset substrate (S11): CSR container, LibSVM parser, synthetic
//! generators matched to the paper's Table 1.

pub mod dataset;
pub mod libsvm;
pub mod synthetic;

pub use dataset::Dataset;
pub use synthetic::{paper_dataset, small_dense, zipf_scenario, PaperDataset, SyntheticSpec};

use std::sync::Arc;

/// Resolve a dataset by name: a real LibSVM file under `data/` if present
/// (e.g. `data/rcv1`), else the synthetic stand-in at the given scale.
///
/// Contended-workload scenarios are first-class names (DESIGN.md §6):
/// `zipf:<s>` is an rcv1-shaped synthetic whose feature popularity follows
/// a power law of exponent `s` (e.g. `zipf:1.2`), and
/// `zipf:<s>:<n>:<d>:<nnz>` pins the shape explicitly (`scale` ignored).
pub fn resolve(name: &str, scale: f64, seed: u64) -> Result<Arc<Dataset>, String> {
    if let Some(rest) = name.strip_prefix("zipf:") {
        let parts: Vec<&str> = rest.split(':').collect();
        let s: f64 = parts[0]
            .parse()
            .map_err(|_| format!("zipf dataset '{name}': bad exponent '{}'", parts[0]))?;
        if s < 0.0 || !s.is_finite() {
            return Err(format!("zipf dataset '{name}': exponent must be finite and >= 0"));
        }
        return match parts.len() {
            1 => Ok(Arc::new(zipf_scenario(s, scale, seed))),
            4 => {
                let dims: Vec<usize> = parts[1..]
                    .iter()
                    .map(|t| t.parse().map_err(|_| format!("zipf dataset '{name}': bad size '{t}'")))
                    .collect::<Result<_, _>>()?;
                let (n, d, nnz) = (dims[0], dims[1], dims[2]);
                if n == 0 || d == 0 || nnz == 0 || nnz > d {
                    return Err(format!("zipf dataset '{name}': need n,d >= 1 and 1 <= nnz <= d"));
                }
                let spec = SyntheticSpec::new(&format!("zipf{s}-{n}x{d}"), n, d, nnz, seed)
                    .with_zipf(s);
                Ok(Arc::new(spec.generate()))
            }
            _ => Err(format!(
                "zipf dataset '{name}': want zipf:<s> or zipf:<s>:<n>:<d>:<nnz>"
            )),
        };
    }
    let which = match name {
        "rcv1" => Some(PaperDataset::Rcv1),
        "real-sim" | "realsim" => Some(PaperDataset::RealSim),
        "news20" => Some(PaperDataset::News20),
        _ => None,
    };
    if let Some(w) = which {
        let path = format!("data/{}", w.name());
        if std::path::Path::new(&path).exists() {
            let (_, d, _) = w.stats();
            let mut ds = libsvm::load_file(&path, Some(d))?;
            ds.l2_normalize_rows();
            return Ok(Arc::new(ds));
        }
        return Ok(Arc::new(paper_dataset(w, scale, seed)));
    }
    if std::path::Path::new(name).exists() {
        let mut ds = libsvm::load_file(name, None)?;
        ds.l2_normalize_rows();
        return Ok(Arc::new(ds));
    }
    Err(format!("unknown dataset '{name}' (and no such file)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_synthetic_fallback() {
        let ds = resolve("rcv1", 0.02, 1).unwrap();
        assert!(ds.name.starts_with("rcv1-synth"));
        assert!(ds.n() > 100);
    }

    #[test]
    fn resolve_unknown_errors() {
        assert!(resolve("no-such-dataset", 1.0, 1).is_err());
    }

    #[test]
    fn resolve_zipf_scenarios() {
        let ds = resolve("zipf:1.2", 0.02, 1).unwrap();
        assert!(ds.name.starts_with("zipf1.2@"));
        let pinned = resolve("zipf:0.9:300:5000:12", 1.0, 1).unwrap();
        assert_eq!((pinned.n(), pinned.dim), (300, 5000));
        // steeper exponent ⇒ hotter head, visible in the concentration stat
        let flat = resolve("zipf:0.0:300:5000:12", 1.0, 1).unwrap();
        assert!(pinned.coord_touch_concentration() > flat.coord_touch_concentration());
        for bad in ["zipf:", "zipf:-1", "zipf:1.0:10", "zipf:1.0:0:5:2", "zipf:1.0:9:5:6"] {
            assert!(resolve(bad, 1.0, 1).is_err(), "{bad} should be rejected");
        }
    }
}
