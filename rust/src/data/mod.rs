//! Dataset substrate (S11): CSR container, LibSVM parser, synthetic
//! generators matched to the paper's Table 1.

pub mod dataset;
pub mod libsvm;
pub mod synthetic;

pub use dataset::Dataset;
pub use synthetic::{paper_dataset, small_dense, PaperDataset, SyntheticSpec};

use std::sync::Arc;

/// Resolve a dataset by name: a real LibSVM file under `data/` if present
/// (e.g. `data/rcv1`), else the synthetic stand-in at the given scale.
pub fn resolve(name: &str, scale: f64, seed: u64) -> Result<Arc<Dataset>, String> {
    let which = match name {
        "rcv1" => Some(PaperDataset::Rcv1),
        "real-sim" | "realsim" => Some(PaperDataset::RealSim),
        "news20" => Some(PaperDataset::News20),
        _ => None,
    };
    if let Some(w) = which {
        let path = format!("data/{}", w.name());
        if std::path::Path::new(&path).exists() {
            let (_, d, _) = w.stats();
            let mut ds = libsvm::load_file(&path, Some(d))?;
            ds.l2_normalize_rows();
            return Ok(Arc::new(ds));
        }
        return Ok(Arc::new(paper_dataset(w, scale, seed)));
    }
    if std::path::Path::new(name).exists() {
        let mut ds = libsvm::load_file(name, None)?;
        ds.l2_normalize_rows();
        return Ok(Arc::new(ds));
    }
    Err(format!("unknown dataset '{name}' (and no such file)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_synthetic_fallback() {
        let ds = resolve("rcv1", 0.02, 1).unwrap();
        assert!(ds.name.starts_with("rcv1-synth"));
        assert!(ds.n() > 100);
    }

    #[test]
    fn resolve_unknown_errors() {
        assert!(resolve("no-such-dataset", 1.0, 1).is_err());
    }
}
