//! LibSVM text-format parser/writer.
//!
//! The paper's datasets (rcv1, real-sim, news20) ship in this format from
//! the LibSVM site. The host has no network, so real files are optional:
//! if `data/<name>` exists we use it; otherwise `synthetic::paper_dataset`
//! provides a statistically matched stand-in (DESIGN.md §2).
//!
//! Format, one instance per line:  `<label> <idx>:<val> <idx>:<val> ...`
//! with 1-based, strictly increasing indices. Labels accepted: ±1, 0/1
//! (mapped to ∓1), or 2-class {1,2} style (mapped 1→+1, 2→−1).

use std::io::{BufRead, BufReader, Read, Write};

use super::dataset::Dataset;

/// Parse from any reader. `dim_hint` lets callers force a feature count
/// (Table 1 dims include trailing all-zero features the file never names).
pub fn parse<R: Read>(r: R, name: &str, dim_hint: Option<usize>) -> Result<Dataset, String> {
    let reader = BufReader::new(r);
    let mut rows: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();
    let mut labels = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let lbl_tok = parts.next().ok_or_else(|| format!("line {}: empty", lineno + 1))?;
        let raw: f32 = lbl_tok
            .parse()
            .map_err(|_| format!("line {}: bad label '{lbl_tok}'", lineno + 1))?;
        let label = normalize_label(raw)
            .ok_or_else(|| format!("line {}: unsupported label {raw}", lineno + 1))?;
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for tok in parts {
            let (i_s, v_s) = tok
                .split_once(':')
                .ok_or_else(|| format!("line {}: bad pair '{tok}'", lineno + 1))?;
            let i: usize = i_s
                .parse()
                .map_err(|_| format!("line {}: bad index '{i_s}'", lineno + 1))?;
            if i == 0 {
                return Err(format!("line {}: libsvm indices are 1-based", lineno + 1));
            }
            let v: f32 = v_s
                .parse()
                .map_err(|_| format!("line {}: bad value '{v_s}'", lineno + 1))?;
            let zero_based = (i - 1) as u32;
            if let Some(&last) = idx.last() {
                if zero_based <= last {
                    return Err(format!("line {}: indices not increasing", lineno + 1));
                }
            }
            max_idx = max_idx.max(i - 1);
            idx.push(zero_based);
            val.push(v);
        }
        rows.push((idx, val));
        labels.push(label);
    }
    if rows.is_empty() {
        return Err("no instances".into());
    }
    let dim = match dim_hint {
        Some(d) if d > max_idx => d,
        Some(d) => {
            return Err(format!("dim_hint {d} <= max index {max_idx}"));
        }
        None => max_idx + 1,
    };
    Dataset::from_rows(rows, labels, dim, name)
}

fn normalize_label(raw: f32) -> Option<f32> {
    match raw {
        r if r == 1.0 => Some(1.0),
        r if r == -1.0 => Some(-1.0),
        r if r == 0.0 => Some(-1.0), // {0,1} convention
        r if r == 2.0 => Some(-1.0), // {1,2} convention
        _ => None,
    }
}

/// Load from a filesystem path.
pub fn load_file(path: &str, dim_hint: Option<usize>) -> Result<Dataset, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{path}: {e}"))?;
    let name = std::path::Path::new(path)
        .file_name()
        .and_then(|s| s.to_str())
        .unwrap_or("dataset");
    parse(f, name, dim_hint)
}

/// Serialize back to LibSVM text (round-trip tests, dataset export).
pub fn write<W: Write>(ds: &Dataset, w: &mut W) -> std::io::Result<()> {
    for i in 0..ds.n() {
        let row = ds.row(i);
        write!(w, "{}", if ds.label(i) > 0.0 { "+1" } else { "-1" })?;
        for k in 0..row.nnz() {
            write!(w, " {}:{}", row.indices[k] + 1, row.values[k])?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "+1 1:0.5 3:1.25\n-1 2:2.0\n# comment line\n\n+1 1:1.0 # trailing\n";

    #[test]
    fn parses_sample() {
        let ds = parse(SAMPLE.as_bytes(), "sample", None).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.dim, 3);
        assert_eq!(ds.labels, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.row(0).indices, &[0, 2]);
        assert_eq!(ds.row(0).values, &[0.5, 1.25]);
        assert_eq!(ds.row(1).indices, &[1]);
    }

    #[test]
    fn dim_hint_expands_but_never_shrinks() {
        let ds = parse(SAMPLE.as_bytes(), "s", Some(10)).unwrap();
        assert_eq!(ds.dim, 10);
        assert!(parse(SAMPLE.as_bytes(), "s", Some(2)).is_err());
    }

    #[test]
    fn label_conventions() {
        let ds = parse("0 1:1\n1 1:1\n2 1:1\n".as_bytes(), "s", None).unwrap();
        assert_eq!(ds.labels, vec![-1.0, 1.0, -1.0]);
        assert!(parse("3 1:1\n".as_bytes(), "s", None).is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["+1 0:1.0\n", "+1 2:1 1:1\n", "+1 x:1\n", "+1 1:y\n", "+1 11\n", ""] {
            assert!(parse(bad.as_bytes(), "s", None).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn round_trip() {
        let ds = parse(SAMPLE.as_bytes(), "sample", None).unwrap();
        let mut buf = Vec::new();
        write(&ds, &mut buf).unwrap();
        let ds2 = parse(buf.as_slice(), "sample", Some(ds.dim)).unwrap();
        assert_eq!(ds.labels, ds2.labels);
        assert_eq!(ds.indices, ds2.indices);
        assert_eq!(ds.values, ds2.values);
        assert_eq!(ds.indptr, ds2.indptr);
    }
}
