//! CSR dataset container for problem (1): instances x_i ∈ R^d (sparse),
//! labels y_i ∈ {−1, +1}.

use std::sync::OnceLock;

use crate::linalg::SparseRow;

/// Immutable CSR training set. `indptr` has n+1 entries; row i occupies
/// `indices[indptr[i]..indptr[i+1]]` / `values[...]`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub indptr: Vec<u64>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    pub labels: Vec<f32>,
    pub dim: usize,
    pub name: String,
    /// Memoized Σ (c_j/nnz)² — the sparsity pattern is immutable after
    /// construction (`l2_normalize_rows` rescales values only), and the
    /// simulator prices this once per inner phase, so the O(nnz + d) pass
    /// must not repeat per epoch.
    touch_concentration: OnceLock<f64>,
    /// Memoized cache-line-granular variant (64 B = 16 f32 coordinates) —
    /// the false-sharing input of `simcore::cost::NumaCost`.
    line_concentration: OnceLock<f64>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.labels.len()
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of non-zeros: nnz / (n·d).
    pub fn density(&self) -> f64 {
        if self.n() == 0 || self.dim == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n() as f64 * self.dim as f64)
    }

    #[inline]
    pub fn row(&self, i: usize) -> SparseRow<'_> {
        let lo = self.indptr[i] as usize;
        let hi = self.indptr[i + 1] as usize;
        SparseRow { indices: &self.indices[lo..hi], values: &self.values[lo..hi] }
    }

    #[inline]
    pub fn label(&self, i: usize) -> f32 {
        self.labels[i]
    }

    /// Build from per-row (indices, values) + labels, validating invariants.
    pub fn from_rows(
        rows: Vec<(Vec<u32>, Vec<f32>)>,
        labels: Vec<f32>,
        dim: usize,
        name: &str,
    ) -> Result<Self, String> {
        if rows.len() != labels.len() {
            return Err(format!("{} rows but {} labels", rows.len(), labels.len()));
        }
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u64);
        for (r, (idx, val)) in rows.into_iter().enumerate() {
            if idx.len() != val.len() {
                return Err(format!("row {r}: {} indices vs {} values", idx.len(), val.len()));
            }
            // indices must be strictly increasing and < dim
            for k in 0..idx.len() {
                if idx[k] as usize >= dim {
                    return Err(format!("row {r}: index {} >= dim {dim}", idx[k]));
                }
                if k > 0 && idx[k] <= idx[k - 1] {
                    return Err(format!("row {r}: indices not strictly increasing"));
                }
            }
            indices.extend_from_slice(&idx);
            values.extend_from_slice(&val);
            indptr.push(indices.len() as u64);
        }
        for (i, &y) in labels.iter().enumerate() {
            if y != 1.0 && y != -1.0 {
                return Err(format!("label {i} = {y}, want ±1"));
            }
        }
        Ok(Dataset {
            indptr,
            indices,
            values,
            labels,
            dim,
            name: name.to_string(),
            touch_concentration: OnceLock::new(),
            line_concentration: OnceLock::new(),
        })
    }

    /// L2-normalize every row in place (standard preprocessing for the
    /// LibSVM text datasets; bounds the per-instance Lipschitz constant by
    /// 0.25 + λ — see `objective::lipschitz`).
    pub fn l2_normalize_rows(&mut self) {
        for i in 0..self.n() {
            let lo = self.indptr[i] as usize;
            let hi = self.indptr[i + 1] as usize;
            let sq: f32 = self.values[lo..hi].iter().map(|v| v * v).sum();
            if sq > 0.0 {
                let inv = 1.0 / sq.sqrt();
                for v in &mut self.values[lo..hi] {
                    *v *= inv;
                }
            }
        }
    }

    /// Mean non-zeros per row.
    pub fn avg_nnz(&self) -> f64 {
        if self.n() == 0 {
            return 0.0;
        }
        self.nnz() as f64 / self.n() as f64
    }

    /// Feature-touch concentration Σ_j (c_j/nnz)², where c_j is how many
    /// rows carry feature j — the probability that two independently
    /// sampled coordinate touches land on the same feature (the Simpson
    /// index of the feature-popularity distribution). A uniform spread
    /// gives 1/d; a Zipfian head pushes it orders of magnitude higher.
    /// This is the skew input of the sparse contention model
    /// (`simcore::SparseContention`, DESIGN.md §6). The O(nnz + d) pass
    /// runs once per dataset and is memoized.
    pub fn coord_touch_concentration(&self) -> f64 {
        *self.touch_concentration.get_or_init(|| {
            let total = self.nnz() as f64;
            if total == 0.0 {
                return 0.0;
            }
            let mut counts = vec![0u32; self.dim];
            for &j in &self.indices {
                counts[j as usize] += 1;
            }
            counts
                .iter()
                .map(|&c| {
                    let f = c as f64 / total;
                    f * f
                })
                .sum()
        })
    }

    /// [`coord_touch_concentration`](Dataset::coord_touch_concentration) at
    /// 64-byte cache-line granularity: Σ_L (c_L/nnz)² with lines of 16 f32
    /// coordinates. Merging buckets can only raise a Simpson index, so this
    /// is always ≥ the coordinate concentration; the *gap* is the collision
    /// mass available only to **false sharing** — two concurrent writes on
    /// one line that touch different coordinates still ping-pong the line.
    /// Input of the NUMA placement billing (`simcore::cost::NumaCost`).
    pub fn line_touch_concentration(&self) -> f64 {
        *self.line_concentration.get_or_init(|| {
            let total = self.nnz() as f64;
            if total == 0.0 {
                return 0.0;
            }
            let lines = self.dim.div_ceil(16);
            let mut counts = vec![0u32; lines];
            for &j in &self.indices {
                counts[j as usize / 16] += 1;
            }
            counts
                .iter()
                .map(|&c| {
                    let f = c as f64 / total;
                    f * f
                })
                .sum()
        })
    }

    /// Max row ‖x_i‖² — the data term in the Lipschitz bound.
    pub fn max_row_sq_norm(&self) -> f32 {
        (0..self.n()).map(|i| self.row(i).sq_norm()).fold(0.0, f32::max)
    }

    /// Densify (tests / XLA dense-path bridging only — O(n·d)).
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        (0..self.n()).map(|i| self.row(i).to_dense(self.dim)).collect()
    }

    /// One-line Table-1-style description.
    pub fn describe(&self) -> String {
        format!(
            "{}: n={} d={} nnz={} density={:.4}%",
            self.name,
            self.n(),
            self.dim,
            self.nnz(),
            self.density() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::from_rows(
            vec![
                (vec![0, 2], vec![1.0, 2.0]),
                (vec![1], vec![-3.0]),
                (vec![], vec![]),
            ],
            vec![1.0, -1.0, 1.0],
            4,
            "tiny",
        )
        .unwrap()
    }

    #[test]
    fn shape_accessors() {
        let d = tiny();
        assert_eq!(d.n(), 3);
        assert_eq!(d.nnz(), 3);
        assert_eq!(d.dim, 4);
        assert_eq!(d.row(0).nnz(), 2);
        assert_eq!(d.row(2).nnz(), 0);
        assert!((d.density() - 3.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_rows() {
        assert!(Dataset::from_rows(
            vec![(vec![5], vec![1.0])],
            vec![1.0],
            4,
            "bad"
        )
        .is_err());
        assert!(Dataset::from_rows(
            vec![(vec![1, 1], vec![1.0, 2.0])],
            vec![1.0],
            4,
            "dup"
        )
        .is_err());
        assert!(Dataset::from_rows(vec![(vec![0], vec![1.0])], vec![0.5], 4, "lbl").is_err());
        assert!(Dataset::from_rows(vec![], vec![1.0], 4, "count").is_err());
    }

    #[test]
    fn normalize_rows() {
        let mut d = tiny();
        d.l2_normalize_rows();
        assert!((d.row(0).sq_norm() - 1.0).abs() < 1e-6);
        assert!((d.row(1).sq_norm() - 1.0).abs() < 1e-6);
        assert_eq!(d.row(2).sq_norm(), 0.0); // empty row untouched
        assert!((d.max_row_sq_norm() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn touch_concentration_bounds_and_extremes() {
        // every row touches the same single feature: concentration = 1
        let hot = Dataset::from_rows(
            vec![(vec![0], vec![1.0]), (vec![0], vec![1.0])],
            vec![1.0, -1.0],
            4,
            "hot",
        )
        .unwrap();
        assert!((hot.coord_touch_concentration() - 1.0).abs() < 1e-12);
        assert_eq!(hot.avg_nnz(), 1.0);
        // perfectly spread: one touch per feature ⇒ 1/d
        let spread = Dataset::from_rows(
            vec![(vec![0, 1], vec![1.0, 1.0]), (vec![2, 3], vec![1.0, 1.0])],
            vec![1.0, -1.0],
            4,
            "spread",
        )
        .unwrap();
        assert!((spread.coord_touch_concentration() - 0.25).abs() < 1e-12);
        // mixed case sits strictly between
        let d = tiny();
        let s = d.coord_touch_concentration();
        assert!(s > 1.0 / 4.0 - 1e-12 && s < 1.0, "s = {s}");
    }

    #[test]
    fn densify_matches_rows() {
        let d = tiny();
        let m = d.to_dense();
        assert_eq!(m[0], vec![1.0, 0.0, 2.0, 0.0]);
        assert_eq!(m[1], vec![0.0, -3.0, 0.0, 0.0]);
        assert_eq!(m[2], vec![0.0; 4]);
    }
}
