#!/usr/bin/env bash
# Fetch the paper's three LIBSVM datasets (Table 1 of arXiv:1508.05711):
#
#   rcv1      20,242 x 47,236   (binary rcv1.binary train split)
#   real-sim  72,309 x 20,958
#   news20    19,996 x 1,355,191
#
# Files land as plain LibSVM text at data/<name>, which is exactly where
# `data::resolve` looks first (rust/src/data/mod.rs); when a file is
# absent the Rust side falls back to the Table-1-shaped synthetic
# stand-in, so fetching is always optional.
#
# Integrity: trust-on-first-use. If data/SHA256SUMS has an entry for a
# file we verify against it; otherwise we record the digest of what we
# downloaded so later fetches (and other machines) are pinned.
#
# Offline-friendly: if neither curl nor wget can reach the mirror the
# script says so and exits 0 — `make data` must never break an air-gapped
# build, because nothing in the repo *requires* the real data.
set -u

cd "$(dirname "$0")"
SUMS=SHA256SUMS
BASE="https://www.csie.ntu.edu.tw/~cjlin/libsvmtools/datasets/binary"

fetch() { # fetch <url> <out>
    if command -v curl >/dev/null 2>&1; then
        curl -fsSL --retry 2 -o "$2" "$1"
    elif command -v wget >/dev/null 2>&1; then
        wget -q -O "$2" "$1"
    else
        echo "fetch.sh: neither curl nor wget available" >&2
        return 1
    fi
}

digest() { # digest <file> -> hex
    if command -v sha256sum >/dev/null 2>&1; then
        sha256sum "$1" | awk '{print $1}'
    else
        shasum -a 256 "$1" | awk '{print $1}'
    fi
}

verify_or_record() { # verify_or_record <file> <fresh: 1 if just downloaded>
    local f="$1" fresh="${2:-0}" have want
    have=$(digest "$f")
    if [ -f "$SUMS" ] && want=$(awk -v f="$f" '$2 == f {print $1}' "$SUMS") && [ -n "${want:-}" ]; then
        if [ "$have" != "$want" ]; then
            echo "fetch.sh: sha256 mismatch for $f" >&2
            echo "  pinned: $want" >&2
            echo "  actual: $have" >&2
            # only discard what we just fetched — never a hand-placed file
            [ "$fresh" = 1 ] && rm -f "$f"
            return 1
        fi
        echo "  $f: sha256 ok"
    else
        echo "$have  $f" >>"$SUMS"
        echo "  $f: sha256 recorded (trust-on-first-use) -> $SUMS"
    fi
}

get_one() { # get_one <name> <remote-bz2-name>
    local name="$1" remote="$2"
    if [ -f "$name" ]; then
        echo "  $name: already present, skipping download"
        verify_or_record "$name" || return 1
        return 0
    fi
    echo "  $name: downloading $remote ..."
    if ! fetch "$BASE/$remote" "$name.bz2"; then
        rm -f "$name.bz2"
        echo "  $name: download failed (offline?) — synthetic stand-in will be used"
        return 0
    fi
    if ! bunzip2 -f "$name.bz2"; then
        rm -f "$name.bz2" "$name"
        echo "fetch.sh: bunzip2 failed for $name" >&2
        return 1
    fi
    verify_or_record "$name" 1
}

rc=0
get_one rcv1 rcv1_train.binary.bz2 || rc=1
get_one real-sim real-sim.bz2 || rc=1
get_one news20 news20.binary.bz2 || rc=1

if [ "$rc" -eq 0 ]; then
    echo "fetch.sh: done. 'repro run --dataset rcv1' now uses the real file."
fi
exit "$rc"
