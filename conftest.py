"""Root conftest: make `pytest python/tests/` work from the workspace root
(the test modules import the build-time `compile` package that lives under
python/)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
