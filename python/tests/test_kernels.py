"""L1 correctness: every Pallas kernel vs the pure-jnp oracle in ref.py.

hypothesis sweeps shapes/seeds/dtypes — this is the CORE correctness signal
for the compute layer (system prompt: kernel vs ref allclose).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.logreg_grad import (
    logreg_grad,
    logreg_grad_bigd,
    logreg_loss,
    mxu_flops,
    vmem_bytes,
)
from compile.kernels.svrg_update import hbm_bytes, svrg_update

jax.config.update("jax_enable_x64", False)

HSET = settings(max_examples=15, deadline=None)


def _data(seed, b, d, dtype=jnp.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, d)) * scale, dtype=dtype)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=b), dtype=dtype)
    w = jnp.asarray(rng.standard_normal(d) * 0.1, dtype=dtype)
    return x, y, w


# --------------------------------------------------------------------- grad


@HSET
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([1, 2, 7, 16, 64, 128, 256]),
    d=st.sampled_from([1, 3, 8, 64, 256]),
    lam=st.sampled_from([0.0, 1e-4, 0.1]),
)
def test_grad_matches_ref(seed, b, d, lam):
    x, y, w = _data(seed, b, d)
    got = logreg_grad(x, y, w, lam, block_b=min(b, 128))
    want = ref.logistic_grad_ref(x, y, w, lam)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@HSET
@given(seed=st.integers(0, 2**31 - 1))
def test_grad_multi_tile_accumulation(seed):
    """grid > 1: the cross-tile accumulator must equal the one-shot ref."""
    x, y, w = _data(seed, 256, 64)
    got = logreg_grad(x, y, w, 1e-4, block_b=32)  # 8 grid steps
    want = ref.logistic_grad_ref(x, y, w, 1e-4)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_grad_extreme_margins_stable():
    """Saturated sigmoids must not produce nan/inf (stable tanh form)."""
    x, y, w = _data(0, 64, 16, scale=100.0)
    g = logreg_grad(x, y, w * 100.0, 1e-4, block_b=64)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_grad_zero_label_rows_contribute_nothing():
    """y=0 padding rows are exactly inert (full-grad chunk padding relies
    on this)."""
    x, y, w = _data(3, 64, 32)
    xp = jnp.concatenate([x, jnp.ones((64, 32))])
    yp = jnp.concatenate([y, jnp.zeros(64)])
    g_pad = logreg_grad(xp, yp, w, 0.0, block_b=128) * 128
    g = logreg_grad(x, y, w, 0.0, block_b=64) * 64
    np.testing.assert_allclose(g_pad, g, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- big-D


@HSET
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([64, 128, 512]),
    block_d=st.sampled_from([32, 64]),
)
def test_grad_bigd_matches_ref(seed, b, d, block_d):
    x, y, w = _data(seed, b, d)
    got = logreg_grad_bigd(x, y, w, 1e-4, block_b=32, block_d=block_d)
    want = ref.logistic_grad_ref(x, y, w, 1e-4)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


def test_bigd_and_batch_tiled_agree():
    x, y, w = _data(9, 128, 256)
    a = logreg_grad(x, y, w, 1e-4)
    bb = logreg_grad_bigd(x, y, w, 1e-4, block_b=64, block_d=64)
    np.testing.assert_allclose(a, bb, rtol=2e-5, atol=2e-6)


# --------------------------------------------------------------------- loss


@HSET
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([1, 8, 64, 128, 256]),
    d=st.sampled_from([4, 32, 256]),
    lam=st.sampled_from([0.0, 1e-4]),
)
def test_loss_matches_ref(seed, b, d, lam):
    x, y, w = _data(seed, b, d)
    got = logreg_loss(x, y, w, lam, block_b=min(b, 128))
    want = ref.logistic_loss_ref(x, y, w, lam)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_loss_at_zero_w_is_log2():
    x, y, w = _data(1, 64, 8)
    got = logreg_loss(x, y, jnp.zeros(8), 0.0, block_b=64)
    np.testing.assert_allclose(got, np.log(2.0), rtol=1e-6)


# --------------------------------------------------------------- svrg step


@HSET
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from([1, 2, 16, 256, 2048, 4096]),
    eta=st.sampled_from([0.0, 1e-3, 0.5]),
)
def test_svrg_update_matches_ref(seed, d, eta):
    rng = np.random.default_rng(seed)
    u, g, g0, mu = (jnp.asarray(rng.standard_normal(d), jnp.float32) for _ in range(4))
    got_u, got_v = svrg_update(u, g, g0, mu, eta)
    want_u, want_v = ref.svrg_update_ref(u, g, g0, mu, eta)
    np.testing.assert_allclose(got_u, want_u, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-6, atol=1e-7)


def test_svrg_update_at_snapshot_is_full_gradient_step():
    """At u = u₀ (g == g0) the direction collapses to μ̄ exactly — the
    variance-reduction identity the paper's Lemma 1 builds on."""
    rng = np.random.default_rng(7)
    u = jnp.asarray(rng.standard_normal(128), jnp.float32)
    g = jnp.asarray(rng.standard_normal(128), jnp.float32)
    mu = jnp.asarray(rng.standard_normal(128), jnp.float32)
    u_new, v = svrg_update(u, g, g, mu, 0.1)
    np.testing.assert_allclose(v, mu, rtol=1e-6)
    np.testing.assert_allclose(u_new, u - 0.1 * mu, rtol=1e-6)


def test_svrg_update_eta_zero_is_identity():
    u = jnp.arange(64, dtype=jnp.float32)
    u_new, _ = svrg_update(u, u * 2, u * 3, u * 4, 0.0)
    np.testing.assert_allclose(u_new, u)


# ----------------------------------------------------- analytic perf models


def test_vmem_budget_default_blocks():
    """Default grad tile must fit a 16 MiB VMEM with double buffering."""
    assert 2 * vmem_bytes(128, 1024) < 16 * 2**20


def test_mxu_flops_positive_and_linear():
    assert mxu_flops(128, 256) == 2 * mxu_flops(64, 256) == 4 * mxu_flops(64, 128)


def test_fused_update_traffic_beats_unfused():
    d = 4096
    unfused = (8 + 3) * d * 4
    assert hbm_bytes(d) < 0.6 * unfused
