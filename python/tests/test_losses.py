"""Multi-loss kernel coverage: the margin-loss family (losses.py) through
the batch-tiled Pallas kernel vs oracle and autodiff, plus dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import losses
from compile.kernels.logreg_grad import margin_grad

HSET = settings(max_examples=12, deadline=None)


def _data(seed, b, d, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, d)), dtype)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=b), dtype)
    w = jnp.asarray(rng.standard_normal(d) * 0.2, dtype)
    return x, y, w


@HSET
@given(
    seed=st.integers(0, 2**31 - 1),
    kind=st.sampled_from(losses.LOSS_KINDS),
    b=st.sampled_from([8, 64, 128]),
    d=st.sampled_from([4, 32, 128]),
)
def test_margin_grad_matches_oracle(seed, kind, b, d):
    x, y, w = _data(seed, b, d)
    got = margin_grad(x, y, w, 1e-3, kind=kind, block_b=min(b, 64))
    want = losses.grad_ref(kind, x, y, w, 1e-3)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


@HSET
@given(seed=st.integers(0, 2**31 - 1), kind=st.sampled_from(losses.LOSS_KINDS))
def test_margin_grad_is_autodiff_gradient(seed, kind):
    x, y, w = _data(seed, 32, 16)
    want = jax.grad(lambda w_: losses.loss_ref(kind, x, y, w_, 1e-3))(w)
    got = margin_grad(x, y, w, 1e-3, kind=kind, block_b=32)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=2e-5)


@HSET
@given(kind=st.sampled_from(losses.LOSS_KINDS), m=st.floats(-5.0, 5.0))
def test_dphi_is_derivative_of_phi(kind, m):
    eps = 1e-3
    m = jnp.float32(m)
    fd = (losses.phi(kind, m + eps) - losses.phi(kind, m - eps)) / (2 * eps)
    np.testing.assert_allclose(losses.dphi(kind, m), fd, rtol=2e-2, atol=2e-3)


def test_squared_hinge_zero_past_margin():
    """Correct hinge behaviour: no gradient once the margin exceeds 1."""
    d = 8
    x = jnp.ones((4, d)) / d
    y = jnp.ones(4)
    w = jnp.ones(d) * 3.0  # margins = 3 > 1
    g = margin_grad(x, y, w, 0.0, kind="squared_hinge", block_b=4)
    np.testing.assert_allclose(g, jnp.zeros(d), atol=1e-7)


def test_squared_loss_closed_form():
    """Least squares: ∇ = Xᵀ(Xw − y)/B + λw exactly."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=16), jnp.float32)
    w = jnp.asarray(rng.standard_normal(8), jnp.float32)
    got = margin_grad(x, y, w, 1e-2, kind="squared", block_b=16)
    want = x.T @ (x @ w - y) / 16 + 1e-2 * w
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_bfloat16_kernel_runs_with_loose_tolerance():
    """dtype sweep: the kernel template must trace and stay sane in bf16."""
    x, y, w = _data(3, 64, 32, dtype=jnp.bfloat16)
    got = margin_grad(x, y, w, jnp.bfloat16(1e-2), kind="logistic", block_b=64)
    want = losses.grad_ref(
        "logistic", x.astype(jnp.float32), y.astype(jnp.float32), w.astype(jnp.float32), 1e-2
    )
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        got.astype(jnp.float32), want, rtol=0.15, atol=0.05
    )
