"""L2 correctness: model-layer functions vs autodiff and the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

HSET = settings(max_examples=10, deadline=None)


def _data(seed, b, d):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=b), jnp.float32)
    w = jnp.asarray(rng.standard_normal(d) * 0.1, jnp.float32)
    return x, y, w


@HSET
@given(seed=st.integers(0, 2**31 - 1), b=st.sampled_from([16, 128]), d=st.sampled_from([8, 64]))
def test_minibatch_grad_is_autodiff_gradient(seed, b, d):
    """model.minibatch_grad == jax.grad of the reference loss — ties the
    hand-derived kernel math to autodiff ground truth."""
    x, y, w = _data(seed, b, d)
    lam = 1e-4
    want = jax.grad(lambda w_: ref.logistic_loss_ref(x, y, w_, lam))(w)
    got = model.minibatch_grad(x, y, w, lam)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=1e-5)


@HSET
@given(seed=st.integers(0, 2**31 - 1))
def test_grad_contrib_assembles_full_gradient(seed):
    """Chunked contributions, assembled the way the rust epoch pass does
    ((1/n)Σ chunks + λw), must equal the one-shot full gradient."""
    x, y, w = _data(seed, 256, 32)
    lam = 1e-4
    chunks = [x[i : i + 64] for i in range(0, 256, 64)]
    ychunks = [y[i : i + 64] for i in range(0, 256, 64)]
    acc = sum(model.grad_contrib(cx, cy, w) for cx, cy in zip(chunks, ychunks))
    got = acc / 256 + lam * w
    want = ref.full_grad_ref(x, y, w, lam)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


@HSET
@given(seed=st.integers(0, 2**31 - 1))
def test_loss_sum_assembles_mean_loss(seed):
    x, y, w = _data(seed, 128, 16)
    lam = 1e-4
    got = model.loss_sum(x, y, w) / 128 + 0.5 * lam * jnp.sum(w * w)
    want = ref.logistic_loss_ref(x, y, w, lam)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_loss_decreases_along_negative_gradient():
    """Sanity: a small step along -∇f decreases f (convexity smoke)."""
    x, y, w = _data(11, 128, 32)
    lam = 1e-4
    g = model.minibatch_grad(x, y, w, lam)
    f0 = model.loss(x, y, w, lam)
    f1 = model.loss(x, y, w - 0.1 * g, lam)
    assert float(f1) < float(f0)


@HSET
@given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([32, 256]))
def test_svrg_step_matches_oracle(seed, d):
    rng = np.random.default_rng(seed)
    u, g, g0, mu = (jnp.asarray(rng.standard_normal(d), jnp.float32) for _ in range(4))
    got = model.svrg_step(u, g, g0, mu, 0.05)
    want = ref.svrg_update_ref(u, g, g0, mu, 0.05)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-6, atol=1e-6)


def test_svrg_variance_reduction_near_snapshot():
    """The defining property (paper §1): near u₀ the variance-reduced
    direction v has (much) lower variance across instance choices than the
    plain SGD direction."""
    rng = np.random.default_rng(42)
    n, d = 256, 16
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n), jnp.float32)
    w0 = jnp.asarray(rng.standard_normal(d) * 0.1, jnp.float32)
    lam = 1e-4
    mu = ref.full_grad_ref(x, y, w0, lam)
    u = w0 + 0.01 * jnp.asarray(rng.standard_normal(d), jnp.float32)

    def inst_grad(i, w):
        return ref.logistic_grad_ref(x[i : i + 1], y[i : i + 1], w, lam)

    v_svrg, v_sgd = [], []
    for i in range(n):
        gi_u = inst_grad(i, u)
        gi_0 = inst_grad(i, w0)
        v_svrg.append(gi_u - gi_0 + mu)
        v_sgd.append(gi_u)
    v_svrg = jnp.stack(v_svrg)
    v_sgd = jnp.stack(v_sgd)
    var = lambda v: float(jnp.mean(jnp.sum((v - jnp.mean(v, 0)) ** 2, axis=1)))
    assert var(v_svrg) < 0.05 * var(v_sgd)
