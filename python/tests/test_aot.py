"""AOT bridge tests: lowering determinism, manifest shape, HLO-text sanity,
and a CPU-PJRT execution round-trip of every artifact (the same path the
rust runtime takes, minus the language boundary)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entry_list_covers_runtime_contract():
    names = [e[0] for e in aot.entries()]
    assert names == ["minibatch_grad", "grad_contrib", "loss_sum", "svrg_step"]


def test_lowering_is_deterministic():
    (_, fn, args) = aot.entries()[3]
    assert aot.lower_entry(fn, args) == aot.lower_entry(fn, args)


def test_hlo_text_is_parseable_module():
    (_, fn, args) = aot.entries()[0]
    text = aot.lower_entry(fn, args)
    assert "HloModule" in text and "ENTRY" in text
    # must be pure HLO (interpret-mode pallas): no Mosaic custom-calls that
    # the CPU PJRT client (and the rust xla crate) cannot execute
    assert "tpu_custom_call" not in text and "mosaic" not in text.lower()


@pytest.fixture(scope="module")
def manifest(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    return aot.build(str(out)), str(out)


def test_manifest_schema(manifest):
    m, out = manifest
    assert m["dim"] == aot.DIM and m["batch"] == aot.BATCH
    for name, e in m["entries"].items():
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        assert e["outputs"] >= 1
        assert all(isinstance(s, list) for s in e["inputs"])
    assert os.path.exists(os.path.join(out, "manifest.json"))
    with open(os.path.join(out, "manifest.json")) as f:
        assert json.load(f)["entries"].keys() == m["entries"].keys()


def _run_artifact(path, args):
    """Execute an HLO-text artifact on CPU PJRT — mirror of rust runtime."""
    with open(path) as f:
        text = f.read()
    # parse text back into an XlaComputation the same way xla-rs does
    comp = xc._xla.hlo_module_from_text(text)
    backend = jax.devices("cpu")[0].client
    exe = backend.compile(
        xc.XlaComputation(comp.as_serialized_hlo_module_proto()).as_serialized_hlo_module_proto()
        if False
        else xc.XlaComputation(comp.as_serialized_hlo_module_proto())
    )
    bufs = [backend.buffer_from_pyval(np.asarray(a)) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


def test_artifact_execution_matches_model(manifest):
    m, out = manifest
    rng = np.random.default_rng(0)
    D, B, C = m["dim"], m["batch"], m["chunk"]
    x = rng.standard_normal((B, D)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=B).astype(np.float32)
    w = (rng.standard_normal(D) * 0.1).astype(np.float32)
    lam = np.asarray([1e-4], np.float32)

    try:
        (got,) = _run_artifact(
            os.path.join(out, m["entries"]["minibatch_grad"]["file"]), [x, y, w, lam]
        )
    except Exception as exc:  # pragma: no cover - depends on xla_client api
        pytest.skip(f"python-side PJRT replay unavailable: {exc}")
    want = np.asarray(model.minibatch_grad(x, y, w, 1e-4))
    np.testing.assert_allclose(np.asarray(got).reshape(-1), want, rtol=3e-5, atol=3e-6)


def test_svrg_step_artifact_numerics(manifest):
    m, out = manifest
    rng = np.random.default_rng(1)
    D = m["dim"]
    u, g, g0, mu = (rng.standard_normal(D).astype(np.float32) for _ in range(4))
    eta = np.asarray([0.05], np.float32)
    try:
        outs = _run_artifact(
            os.path.join(out, m["entries"]["svrg_step"]["file"]), [u, g, g0, mu, eta]
        )
    except Exception as exc:  # pragma: no cover
        pytest.skip(f"python-side PJRT replay unavailable: {exc}")
    want_u, want_v = ref.svrg_update_ref(u, g, g0, mu, 0.05)
    flat = [np.asarray(o).reshape(-1) for o in outs]
    # return_tuple lowering may pack outputs; find both vectors
    found_u = any(np.allclose(f, want_u, rtol=1e-5) for f in flat)
    found_v = any(np.allclose(f, want_v, rtol=1e-5) for f in flat)
    assert found_u and found_v
