"""L1 Pallas kernels for the logistic-regression compute hot-spot.

The paper's inner loop evaluates per-instance gradients ∇f_i(u); the batched
form (a (B, D) slab of instances) is the hot-spot we put on the MXU:

    z = X w                (B,)   — forward matmul
    r = -y · σ(-y z)       (B,)   — elementwise residual (VPU)
    g = Xᵀ r / B + λ w     (D,)   — backward matmul + epilogue

TPU schedule (DESIGN.md §3): the grid walks batch tiles; each step streams an
(Bt, D) block of X HBM→VMEM via BlockSpec, does both matmuls against the
resident w, and accumulates the partial gradient into the (D,) output block —
the TPU analogue of the paper's per-thread partial gradients φ_a. `w` and the
accumulator stay VMEM-resident across the whole grid (index_map pinned to 0).

Everything is lowered with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU efficiency is estimated analytically in
EXPERIMENTS.md §Perf from the block shapes chosen here.

Kernels:
  * logreg_grad(x, y, w, lam)        -> (D,) gradient   [batch-tiled]
  * logreg_loss(x, y, w, lam)        -> () mean loss + L2 [batch-tiled]
  * logreg_grad_bigd(x, y, w, lam)   -> (D,) gradient   [two-pass, feature-
        tiled backward; the large-D schedule for D ≫ VMEM, e.g. news20's
        1.36M features]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. Bt*D floats of X per grid step must fit VMEM (~16 MiB
# per TPU core): 128 * 1024 * 4 B = 512 KiB — comfortably double-bufferable.
DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_D = 512


from .losses import residual as _loss_residual


def _residual(y, z):
    """r = -y · σ(-y z), stable tanh form (matches ref.sigmoid)."""
    m = y * z
    return -y * (0.5 * (jnp.tanh(-0.5 * m) + 1.0))


# --------------------------------------------------------------------------
# Batch-tiled gradient: grid over batch; w + accumulator VMEM-resident.
# One kernel template serves every margin loss (losses.LOSS_KINDS) — the
# loss is baked at trace time, so each artifact stays a single fused kernel.
# --------------------------------------------------------------------------


def _make_grad_kernel(kind: str):
    def _grad_kernel(x_ref, y_ref, w_ref, g_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            g_ref[...] = jnp.zeros_like(g_ref)

        x = x_ref[...]  # (Bt, D)
        w = w_ref[...]  # (D,)
        z = x @ w  # MXU: (Bt, D) x (D,)
        r = _loss_residual(kind, y_ref[...], z)  # VPU elementwise
        g_ref[...] += r @ x  # MXU: (Bt,) x (Bt, D) — the Xᵀr partial

    return _grad_kernel


def margin_grad(x, y, w, lam, *, kind: str = "logistic", block_b: int = DEFAULT_BLOCK_B):
    """Batched margin-loss gradient, batch-tiled Pallas kernel + epilogue.

    Requires B % block_b == 0 (the AOT artifacts use fixed shapes; the L2
    model pads odd batches before calling).
    """
    b, d = x.shape
    block_b = min(block_b, b)
    assert b % block_b == 0, f"batch {b} not divisible by block {block_b}"
    grid = (b // block_b,)
    g = pl.pallas_call(
        _make_grad_kernel(kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, y, w)
    # epilogue: mean over batch + ridge term (elementwise, XLA fuses it)
    return g / b + lam * w


def logreg_grad(x, y, w, lam, *, block_b: int = DEFAULT_BLOCK_B):
    """The paper's objective: logistic margin loss (see `margin_grad`)."""
    return margin_grad(x, y, w, lam, kind="logistic", block_b=block_b)


# --------------------------------------------------------------------------
# Batch-tiled loss: scalar accumulator kept as a (1,) block.
# --------------------------------------------------------------------------


def _loss_kernel(x_ref, y_ref, w_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = x_ref[...] @ w_ref[...]
    m = y_ref[...] * z
    # softplus-stable log(1 + e^{-m})
    losses = jnp.maximum(-m, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(m)))
    acc_ref[...] += jnp.sum(losses)[None]


def logreg_loss(x, y, w, lam, *, block_b: int = DEFAULT_BLOCK_B):
    """Mean logistic loss + (λ/2)||w||², batch-tiled."""
    b, d = x.shape
    block_b = min(block_b, b)
    assert b % block_b == 0
    grid = (b // block_b,)
    acc = pl.pallas_call(
        _loss_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(x, y, w)
    return acc[0] / b + 0.5 * lam * jnp.sum(w * w)


# --------------------------------------------------------------------------
# Two-pass large-D schedule: pass 1 accumulates z over feature tiles,
# pass 2 walks a (batch, feature) grid for the backward matmul so only a
# (Bt, Dt) block of X is ever VMEM-resident. This is the schedule that
# scales to news20-sized D; on this CPU host it is exercised at small shapes.
# --------------------------------------------------------------------------


def _z_kernel(x_ref, w_ref, z_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    z_ref[...] += x_ref[...] @ w_ref[...]


def _bwd_kernel(x_ref, r_ref, g_ref):
    i = pl.program_id(1)  # batch tile index (minor: accumulate over it)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    g_ref[...] += r_ref[...] @ x_ref[...]


def logreg_grad_bigd(
    x, y, w, lam, *, block_b: int = DEFAULT_BLOCK_B, block_d: int = DEFAULT_BLOCK_D
):
    """Feature-tiled two-pass gradient for D that exceeds VMEM."""
    b, d = x.shape
    block_b = min(block_b, b)
    block_d = min(block_d, d)
    assert b % block_b == 0 and d % block_d == 0
    # pass 1: z = X w, accumulating over feature tiles
    z = pl.pallas_call(
        _z_kernel,
        grid=(d // block_d,),
        in_specs=[
            pl.BlockSpec((b, block_d), lambda j: (0, j)),
            pl.BlockSpec((block_d,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), x.dtype),
        interpret=True,
    )(x, w)
    r = _residual(y, z)
    # pass 2: g = Xᵀ r over a (feature, batch) grid; batch is the minor
    # (fastest-varying) axis so each g block accumulates then retires.
    g = pl.pallas_call(
        _bwd_kernel,
        grid=(d // block_d, b // block_b),
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda j, i: (i, j)),
            pl.BlockSpec((block_b,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, r)
    return g / b + lam * w


def vmem_bytes(block_b: int, d_or_block_d: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grad grid step (X tile + w + g acc).

    Used by the §Perf analysis and by tests that pin the footprint budget.
    """
    x_tile = block_b * d_or_block_d * dtype_bytes
    w_res = d_or_block_d * dtype_bytes
    g_acc = d_or_block_d * dtype_bytes
    z_r = 2 * block_b * dtype_bytes
    return x_tile + w_res + g_acc + z_r


def mxu_flops(block_b: int, d: int) -> int:
    """MACs*2 per grid step (fwd + bwd matmul) — roofline numerator."""
    return 2 * 2 * block_b * d


__all__ = [
    "logreg_grad",
    "margin_grad",
    "logreg_loss",
    "logreg_grad_bigd",
    "vmem_bytes",
    "mxu_flops",
    "DEFAULT_BLOCK_B",
    "DEFAULT_BLOCK_D",
]
