"""L1 Pallas kernel for the fused SVRG inner-loop update (paper eq. 2 + 5).

    v  = g − g₀ + μ̄        (variance-reduced direction)
    u⁺ = u − η v

Fusing the four elementwise streams into one kernel gives a single
HBM read of (u, g, g₀, μ̄) and a single write of (u⁺, v) per feature tile —
on TPU this is purely VPU + DMA work, bandwidth-bound, so the only knob is
tile size (big enough to amortize DMA setup, small enough to double-buffer).

η arrives as a (1,) array so one compiled artifact serves every step size
(the paper sweeps η; re-lowering per η would be silly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 2048


def _update_kernel(u_ref, g_ref, g0_ref, mu_ref, eta_ref, u_out_ref, v_ref):
    v = g_ref[...] - g0_ref[...] + mu_ref[...]
    v_ref[...] = v
    u_out_ref[...] = u_ref[...] - eta_ref[0] * v


def svrg_update(u, g, g0, mu, eta, *, block_d: int = DEFAULT_BLOCK_D):
    """Fused SVRG step. Returns (u_new, v). eta: scalar or (1,) array."""
    d = u.shape[0]
    block_d = min(block_d, d)
    assert d % block_d == 0, f"dim {d} not divisible by block {block_d}"
    eta_arr = jnp.asarray(eta, dtype=u.dtype).reshape((1,))
    grid = (d // block_d,)
    tile = lambda: pl.BlockSpec((block_d,), lambda i: (i,))
    u_new, v = pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            tile(),
            tile(),
            tile(),
            tile(),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[tile(), tile()],
        out_shape=[
            jax.ShapeDtypeStruct((d,), u.dtype),
            jax.ShapeDtypeStruct((d,), u.dtype),
        ],
        interpret=True,
    )(u, g, g0, mu, eta_arr)
    return u_new, v


def hbm_bytes(d: int, dtype_bytes: int = 4) -> int:
    """Total HBM traffic of one fused update (4 reads + 2 writes of (D,)).

    The unfused form costs 8 reads + 3 writes (v materialized, then u read
    again) — the fusion saves ~45% of traffic; asserted in tests and cited
    in EXPERIMENTS.md §Perf.
    """
    return (4 + 2) * d * dtype_bytes


__all__ = ["svrg_update", "hbm_bytes", "DEFAULT_BLOCK_D"]
