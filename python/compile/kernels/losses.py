"""Margin-loss family for the L1 kernels — mirrors rust `objective::LossKind`.

Each loss supplies the per-example residual r = φ′(m)·y and the loss value
φ(m) as traceable jnp functions, so one Pallas kernel template serves
logistic regression, smoothed-hinge SVM, and least squares (the problem
family the paper's eq. (1) covers).
"""

from __future__ import annotations

import jax.numpy as jnp

LOSS_KINDS = ("logistic", "squared_hinge", "squared")


def phi(kind: str, m):
    """Loss value at margin m (softplus-stable for logistic)."""
    if kind == "logistic":
        return jnp.maximum(-m, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(m)))
    if kind == "squared_hinge":
        t = jnp.maximum(1.0 - m, 0.0)
        return t * t
    if kind == "squared":
        return 0.5 * (1.0 - m) * (1.0 - m)
    raise ValueError(f"unknown loss kind {kind!r}")


def dphi(kind: str, m):
    """dφ/dm (stable tanh form for logistic)."""
    if kind == "logistic":
        return -(0.5 * (1.0 - jnp.tanh(0.5 * m)))
    if kind == "squared_hinge":
        return -2.0 * jnp.maximum(1.0 - m, 0.0)
    if kind == "squared":
        return m - 1.0
    raise ValueError(f"unknown loss kind {kind!r}")


def residual(kind: str, y, z):
    """r = φ′(y·z)·y — the scalar with ∇f_i = r·x_i + λw."""
    return dphi(kind, y * z) * y


def grad_ref(kind: str, x, y, w, lam):
    """Oracle batched gradient for any loss kind."""
    r = residual(kind, y, x @ w)
    return x.T @ r / x.shape[0] + lam * w


def loss_ref(kind: str, x, y, w, lam):
    """Oracle mean loss + ridge for any loss kind."""
    return jnp.mean(phi(kind, y * (x @ w)))+ 0.5 * lam * jnp.sum(w * w)
