"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `*_ref` twin to float32 tolerance (pytest enforces this, with
hypothesis sweeping shapes/seeds). They are also what the L2 model falls back
to for shapes the kernels don't cover.

Notation matches the paper (Zhao & Li 2015, §5): L2-regularized logistic
regression,  f(w) = (1/n) Σ log(1 + exp(-y_i x_i^T w)) + (λ/2)||w||².
Labels are y ∈ {-1, +1}.
"""

from __future__ import annotations

import jax.numpy as jnp


def sigmoid(z):
    """Numerically stable logistic function."""
    return 0.5 * (jnp.tanh(0.5 * z) + 1.0)


def logistic_loss_ref(x, y, w, lam):
    """Mean logistic loss + (λ/2)||w||² over a (B, D) batch.

    Uses the softplus-stable form log(1+e^{-m}) = max(-m,0) + log1p(e^{-|m|}).
    """
    margins = y * (x @ w)  # (B,)
    losses = jnp.maximum(-margins, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(margins)))
    return jnp.mean(losses) + 0.5 * lam * jnp.sum(w * w)


def logistic_residual_ref(x, y, w):
    """Per-example dloss/dmargin · y  —  r_i = -y_i · σ(-y_i x_iᵀ w)."""
    margins = y * (x @ w)
    return -y * sigmoid(-margins)


def logistic_grad_ref(x, y, w, lam):
    """∇ of `logistic_loss_ref` w.r.t. w: (1/B) Xᵀ r + λ w."""
    r = logistic_residual_ref(x, y, w)
    return x.T @ r / x.shape[0] + lam * w


def svrg_update_ref(u, g, g0, mu, eta):
    """One SVRG inner step (paper eq. 2):

        v  = ∇f_i(u) − ∇f_i(u₀) + ∇f(u₀)     (g, g0, mu respectively)
        u⁺ = u − η v

    Returns (u_new, v).
    """
    v = g - g0 + mu
    return u - eta * v, v


def full_grad_ref(x, y, w, lam):
    """Full-batch gradient ∇f(w) over the whole (N, D) matrix."""
    return logistic_grad_ref(x, y, w, lam)
