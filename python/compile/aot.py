"""AOT bridge: lower the L2 model to HLO *text* artifacts for the rust L3.

Interchange is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`). The
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Run once per source change (`make artifacts`):

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs one ``<entry>.hlo.txt`` per manifest entry plus ``manifest.json``
describing name → file, input shapes/dtypes, output arity. The rust
`runtime::artifact` module reads the manifest and refuses shape mismatches
at load time instead of at execute time.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Frozen artifact shapes. These are the dense-workload shapes the rust e2e
# driver uses (examples/e2e_pipeline.rs). B and D tile the kernel defaults.
# ---------------------------------------------------------------------------
DIM = 256          # feature dim of the dense e2e workload
BATCH = 128        # minibatch rows per stochastic gradient
CHUNK = 256        # rows per full-gradient / loss streaming chunk

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def entries():
    """(name, fn, example_args) for every artifact we ship."""
    return [
        (
            "minibatch_grad",
            lambda x, y, w, lam: (model.minibatch_grad(x, y, w, lam[0]),),
            (_spec(BATCH, DIM), _spec(BATCH), _spec(DIM), _spec(1)),
        ),
        (
            "grad_contrib",
            lambda x, y, w: (model.grad_contrib(x, y, w),),
            (_spec(CHUNK, DIM), _spec(CHUNK), _spec(DIM)),
        ),
        (
            "loss_sum",
            lambda x, y, w: (model.loss_sum(x, y, w).reshape((1,)),),
            (_spec(CHUNK, DIM), _spec(CHUNK), _spec(DIM)),
        ),
        (
            "svrg_step",
            lambda u, g, g0, mu, eta: model.svrg_step(u, g, g0, mu, eta),
            (_spec(DIM), _spec(DIM), _spec(DIM), _spec(DIM), _spec(1)),
        ),
    ]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "dim": DIM,
        "batch": BATCH,
        "chunk": CHUNK,
        "dtype": "f32",
        "entries": {},
    }
    for name, fn, example_args in entries():
        text = lower_entry(fn, example_args)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        n_out = len(fn(*[jnp.zeros(s.shape, s.dtype) for s in example_args]))
        manifest["entries"][name] = {
            "file": fname,
            "inputs": [list(s.shape) for s in example_args],
            "outputs": n_out,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"  {name}: {len(text)} chars -> {fname}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    print(f"lowering AOT artifacts (D={DIM}, B={BATCH}, chunk={CHUNK})")
    build(args.out_dir)
    print(f"manifest -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
