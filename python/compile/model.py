"""L2: the JAX compute graph for AsySVRG's logistic-regression objective.

This is the build-time model layer. It composes the L1 Pallas kernels into
the exact entry points the rust coordinator executes via PJRT:

  * ``minibatch_grad(x, y, w, lam)`` — scaled stochastic gradient of a
    (B, D) slab; the inner-loop hot-spot.
  * ``grad_contrib(x, y, w)``       — *unscaled* Σᵢ xᵢ rᵢ contribution; the
    full-gradient pass streams the dataset through this in fixed-size chunks
    and the rust side does the final /n + λw (padding rows carry y = 0,
    which contributes exactly zero — see `logistic_residual_ref`).
  * ``loss_sum(x, y, w)``           — unscaled Σ losses for the convergence
    monitor (rust adds /n and the ridge term).
  * ``svrg_step(u, g, g0, mu, eta)``— fused variance-reduced update
    (paper eq. 2); returns (u⁺, v).

Every function is shape-polymorphic at trace time; `aot.py` freezes the
shapes listed in its manifest. Fallback paths use the pure-jnp oracle when a
shape doesn't tile (only reachable in tests — artifacts always tile).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref
from .kernels.logreg_grad import (
    DEFAULT_BLOCK_B,
    logreg_grad,
    logreg_loss,
)
from .kernels.svrg_update import DEFAULT_BLOCK_D, svrg_update


def _tiles(b: int, block: int) -> bool:
    return b % min(block, b) == 0


def minibatch_grad(x, y, w, lam):
    """Scaled stochastic gradient over a (B, D) minibatch: (1/B)Xᵀr + λw."""
    lam = jnp.asarray(lam, dtype=x.dtype).reshape(())
    if _tiles(x.shape[0], DEFAULT_BLOCK_B):
        return logreg_grad(x, y, w, lam)
    return ref.logistic_grad_ref(x, y, w, lam)


def grad_contrib(x, y, w):
    """Unscaled Σᵢ xᵢ rᵢ over a chunk — building block of ∇f(w_t).

    The epoch-boundary full gradient (Alg. 1 line 3) is
        ∇f(w) = (1/n) Σ chunks grad_contrib + λ w,
    assembled by the rust coordinator across its thread partition φ_a.
    """
    if _tiles(x.shape[0], DEFAULT_BLOCK_B):
        # reuse the batch-tiled kernel with λ=0 and undo its 1/B scaling
        g = logreg_grad(x, y, w, jnp.asarray(0.0, dtype=x.dtype))
        return g * x.shape[0]
    r = ref.logistic_residual_ref(x, y, w)
    return x.T @ r


def loss_sum(x, y, w):
    """Unscaled Σ logistic losses over a chunk (no mean, no ridge)."""
    if _tiles(x.shape[0], DEFAULT_BLOCK_B):
        zero = jnp.asarray(0.0, dtype=x.dtype)
        # kernel returns mean + reg(0); undo the mean
        return logreg_loss(x, y, w, zero) * x.shape[0]
    m = y * (x @ w)
    return jnp.sum(jnp.maximum(-m, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(m))))


def svrg_step(u, g, g0, mu, eta):
    """One fused SVRG inner update. Returns (u_new, v)."""
    if _tiles(u.shape[0], DEFAULT_BLOCK_D):
        return svrg_update(u, g, g0, mu, eta)
    return ref.svrg_update_ref(u, g, g0, mu, eta)


def loss(x, y, w, lam):
    """Mean loss + ridge — convenience for tests and the AOT loss entry."""
    lam = jnp.asarray(lam, dtype=x.dtype).reshape(())
    return loss_sum(x, y, w) / x.shape[0] + 0.5 * lam * jnp.sum(w * w)


__all__ = [
    "minibatch_grad",
    "grad_contrib",
    "loss_sum",
    "svrg_step",
    "loss",
]
