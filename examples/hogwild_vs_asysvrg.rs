//! The paper's headline comparison (Fig. 1 right column / Table 3):
//! AsySVRG's linear convergence vs Hogwild!'s sublinear convergence at
//! equal effective passes, 10 simulated cores, all three datasets.
//!
//!     cargo run --release --example hogwild_vs_asysvrg

use asysvrg::bench::{fig1_convergence, BenchEnv};
use asysvrg::data::PaperDataset;

fn main() {
    let env = BenchEnv { scale: 0.05, max_epochs: 30, ..Default::default() };
    for which in [PaperDataset::Rcv1, PaperDataset::RealSim] {
        println!("=== {} (scale {}) ===", which.name(), env.scale);
        let series = fig1_convergence(&env, which, 10);
        // print log10(gap) at a few pass milestones for each method
        println!("{:>16} | {:>9} | {:>9} | {:>9}", "method", "~10 pass", "~30 pass", "final");
        for s in &series {
            let at = |target: f64| {
                s.passes
                    .iter()
                    .position(|&p| p >= target)
                    .map(|i| s.gap[i].log10())
                    .unwrap_or_else(|| *s.gap.last().unwrap() as f64)
            };
            println!(
                "{:>16} | {:>9.2} | {:>9.2} | {:>9.2}",
                s.label,
                at(10.0),
                at(30.0),
                s.gap.last().unwrap().log10()
            );
        }
        let asy = series.iter().find(|s| s.label == "AsySVRG-unlock").unwrap();
        let hog = series.iter().find(|s| s.label == "Hogwild-unlock").unwrap();
        println!(
            "final gap ratio (hogwild/asysvrg): {:.1}x\n",
            hog.gap.last().unwrap() / asy.gap.last().unwrap()
        );
    }
    println!("(values are log10 of the suboptimality gap; lower = better)");
}
