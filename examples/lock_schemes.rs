//! Compare ALL five access schemes — the paper's three (consistent,
//! inconsistent, unlock) plus our two extensions (seqlock, atomic-cas) —
//! on simulated cores, reporting time-to-gap, speedup and empirical τ.
//!
//!     cargo run --release --example lock_schemes

use asysvrg::config::{RunConfig, Scheme};
use asysvrg::coordinator::asysvrg::solve_fstar;
use asysvrg::data;
use asysvrg::objective::Objective;
use asysvrg::simcore::{sim_run, CostModel};

fn main() {
    let ds = data::resolve("rcv1", 0.05, 42).expect("dataset");
    println!("dataset: {}\n", ds.describe());
    let obj = Objective::paper(ds);
    let (_, fstar) = solve_fstar(&obj, 0.4, 120, 7);
    let costs = CostModel::default_host();
    let schemes = [
        Scheme::Consistent,
        Scheme::Inconsistent,
        Scheme::Unlock,
        Scheme::Seqlock,
        Scheme::AtomicCas,
    ];

    println!(
        "{:>14} | {:>9} | {:>9} | {:>8} | {:>9} | {:>10}",
        "scheme", "1-thread", "10-thread", "speedup", "max tau", "mean tau"
    );
    println!("{}", "-".repeat(74));
    for scheme in schemes {
        let cfg = |threads| RunConfig {
            threads,
            scheme,
            eta: 0.4,
            epochs: 60,
            target_gap: 1e-4,
            ..Default::default()
        };
        let base = sim_run(&obj, &cfg(1), &costs, fstar);
        let par = sim_run(&obj, &cfg(10), &costs, fstar);
        let t1 = base.time_to_gap(fstar, 1e-4).unwrap_or(base.total_seconds);
        let tp = par.time_to_gap(fstar, 1e-4).unwrap_or(par.total_seconds);
        println!(
            "{:>14} | {:>8.3}s | {:>8.3}s | {:>7.2}x | {:>9} | {:>10.2}",
            scheme.name(),
            t1,
            tp,
            t1 / tp,
            par.max_delay,
            par.mean_delay
        );
    }
    println!("\n(simulated seconds; paper Table 2 shape: consistent plateaus, unlock scales)");
}
