//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! L1 Pallas kernels (batch-tiled logistic gradient, fused SVRG update) →
//! L2 JAX model → AOT HLO-text artifacts → L3 rust coordinator executing
//! them through PJRT, training dense logistic regression with minibatch
//! SVRG. Python is nowhere at runtime; numerics are audited each epoch
//! against the native rust twin.
//!
//! Requires `make artifacts` first.
//!
//!     cargo run --release --example e2e_pipeline

use asysvrg::bench::e2e;

fn main() {
    let report = match e2e::train(2048, 10, 0.8, 42) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("e2e pipeline failed: {e:#}");
            eprintln!("hint: run `make artifacts` to build the AOT HLO artifacts first");
            std::process::exit(2);
        }
    };
    println!("\n=== e2e pipeline report ===");
    println!("initial loss     : {:.6}", report.initial_loss);
    println!("final loss       : {:.6}", report.final_loss);
    println!("epochs           : {}", report.epochs);
    println!("svrg updates     : {}", report.updates);
    println!("xla grad calls   : {}", report.xla_grad_calls);
    println!("mean grad call   : {:.3} ms", report.mean_grad_call_ms);
    println!("xla-vs-native max loss divergence: {:.2e}", report.max_native_loss_divergence);
    assert!(report.final_loss < report.initial_loss, "training must reduce the loss");
    assert!(
        report.max_native_loss_divergence < 1e-4,
        "XLA and native numerics diverged"
    );
    println!("OK: all three layers compose; loss reduced by {:.1}%",
        100.0 * (report.initial_loss - report.final_loss) / report.initial_loss);
}
