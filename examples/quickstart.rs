//! Quickstart: train L2-regularized logistic regression with AsySVRG on an
//! rcv1-like dataset using the real-threads engine, and print the
//! convergence history.
//!
//!     cargo run --release --example quickstart

use asysvrg::config::{RunConfig, Scheme};
use asysvrg::coordinator;
use asysvrg::data;
use asysvrg::objective::Objective;

fn main() {
    // rcv1 stand-in at 5% scale (real LibSVM file used if present in data/)
    let ds = data::resolve("rcv1", 0.05, 42).expect("dataset");
    println!("dataset: {}", ds.describe());
    let obj = Objective::paper(ds);
    println!(
        "objective: logistic + L2, lambda={}, L={:.4}, kappa={:.0}",
        obj.lam,
        obj.lipschitz(),
        obj.lipschitz() as f64 / obj.strong_convexity() as f64
    );

    // reference optimum from a long sequential run
    let (_, fstar) = coordinator::asysvrg::solve_fstar(&obj, 0.4, 120, 7);
    println!("f* = {fstar:.8}\n");

    let cfg = RunConfig {
        threads: 4,
        scheme: Scheme::Inconsistent,
        eta: 0.4,
        epochs: 30,
        target_gap: 1e-4,
        ..Default::default()
    };
    println!("running: {}", cfg.describe());
    let r = coordinator::run(&obj, &cfg, fstar);

    println!("{:>7} {:>12} {:>12}", "passes", "loss", "gap");
    for h in &r.history {
        println!("{:>7.0} {:>12.6} {:>12.3e}", h.passes, h.loss, h.loss - fstar);
    }
    println!(
        "\nconverged={} in {} epochs / {:.2}s wall; {} updates; empirical tau={} (mean {:.2})",
        r.converged, r.epochs_run, r.total_seconds, r.total_updates, r.max_delay, r.mean_delay
    );
}
