//! Theory meets practice: compute the Theorem 1/2 contraction factors for
//! the experimental constants, then run the simulator and check that
//! (a) the empirical staleness respects the τ the step size was chosen
//! for, and (b) the observed per-epoch contraction beats the worst-case α.
//!
//!     cargo run --release --example theory_bounds

use asysvrg::config::{RunConfig, Scheme};
use asysvrg::coordinator::asysvrg::solve_fstar;
use asysvrg::data;
use asysvrg::objective::Objective;
use asysvrg::simcore::{sim_run, CostModel};
use asysvrg::theory::{theorem1_alpha, theorem2_alpha, RateParams};

fn main() {
    let ds = data::resolve("rcv1", 0.05, 42).expect("dataset");
    let obj = Objective::paper(ds);
    let n = obj.n();
    let p = 10usize;
    let m_tilde = 2 * n as u64;
    let l = obj.lipschitz() as f64;
    let mu = obj.strong_convexity() as f64;
    println!("constants: n={n} L={l:.4} mu={mu:.1e} M~={m_tilde} p={p}");

    println!("\nworst-case rates (tau = p-1 = {}):", p - 1);
    for eta in [0.4, 0.1, 0.01, 0.001] {
        let params = RateParams { mu, l, eta, tau: (p - 1) as u32, m_tilde };
        let t1 = theorem1_alpha(&params)
            .map(|r| format!("alpha={:.4} (rho={:.3})", r.alpha, r.rho))
            .unwrap_or_else(|| "infeasible".into());
        let t2 = theorem2_alpha(&params)
            .map(|r| format!("alpha={:.4} (rho={:.3})", r.alpha, r.rho))
            .unwrap_or_else(|| "infeasible".into());
        println!("  eta={eta:<6}: thm1 {t1:<32} thm2 {t2}");
    }

    // empirical check at the practical step size
    let (_, fstar) = solve_fstar(&obj, 0.4, 120, 7);
    let cfg = RunConfig {
        threads: p,
        scheme: Scheme::Inconsistent,
        eta: 0.4,
        epochs: 20,
        target_gap: 0.0,
        ..Default::default()
    };
    let r = sim_run(&obj, &cfg, &CostModel::default_host(), fstar);
    println!("\nempirical (sim, 10 cores, eta=0.4):");
    println!("  max staleness tau^ = {} (bound assumed: {})", r.max_delay, p - 1);
    let mut rates = Vec::new();
    for w in r.history.windows(2) {
        let g0 = w[0].loss - fstar;
        let g1 = w[1].loss - fstar;
        if g0 > 1e-12 && g1 > 0.0 {
            rates.push(g1 / g0);
        }
    }
    let gmean = (rates.iter().map(|x| x.ln()).sum::<f64>() / rates.len() as f64).exp();
    println!("  observed per-epoch contraction (geo-mean): {gmean:.4}");
    println!(
        "  (worst-case alpha at this eta is infeasible/large — the paper's\n   \
         'relatively large step size works in practice' observation, §5.1)"
    );
    assert!(r.max_delay <= (p - 1) as u64, "staleness exceeded simulated-core bound");
    assert!(gmean < 1.0, "no contraction observed");
    println!("OK");
}
