#!/usr/bin/env python3
"""Render nightly BENCH_*.json artifact series into a TRENDS.md page.

nightly.yml uploads one date-stamped results directory per run (the
BENCH_*.json reports plus NIGHTLY_STAMP.txt). Point this script at any
number of those directories — e.g. a handful of downloaded artifacts —
and it renders one markdown page of trend tables: throughput
(epochs/sec), contention calibration (fitted kappa / collision_ns /
peak measured collision rate), the gated speedups (sparse, epoch pass,
pool dispatch, SIMD inner loops, NUMA hot-head sharding), the NUMA
per-effect billing deltas, and serving latency. Missing reports render
as an em dash, never an error: early artifacts predate newer benches.

Zero-dependency (stdlib only), like everything else in ci/. Usage:

    python3 ci/render_trends.py --results rust/results --out TRENDS.md
    python3 ci/render_trends.py --results night1 --results night2 ...
"""

import argparse
import json
import sys
from pathlib import Path

# column label -> (report filename, extractor over the parsed report).
# Extractors may assume nothing about the report beyond dict-ness: any
# KeyError/TypeError means "metric absent in this run" and renders as —.
METRICS = {
    "throughput": [
        ("pool eps", "BENCH_pool.json", lambda r: r["pool_epochs_per_sec"]),
        ("legacy eps", "BENCH_pool.json", lambda r: r["legacy_epochs_per_sec"]),
        ("async eps", "BENCH_distributed.json", lambda r: r["async_epochs_per_sec"]),
        ("sync eps", "BENCH_distributed.json", lambda r: r["sync_epochs_per_sec"]),
        ("loaded eps", "BENCH_serving.json", lambda r: r["loaded_epochs_per_sec"]),
        ("quiet eps", "BENCH_serving.json", lambda r: r["quiet_epochs_per_sec"]),
    ],
    "contention calibration": [
        ("kappa", "BENCH_contention.json", lambda r: r["fitted"]["kappa"]),
        ("collision_ns", "BENCH_contention.json", lambda r: r["fitted"]["collision_ns"]),
        (
            "peak rate",
            "BENCH_contention.json",
            lambda r: max(p["collision_rate"] for p in r["points"]),
        ),
        ("telemetry ovh", "BENCH_contention.json", lambda r: r["telemetry_overhead"]),
    ],
    "gated speedups": [
        ("sparse", "BENCH_sparse_vs_dense.json", lambda r: r["sparse_speedup"]),
        ("epoch pass", "BENCH_epoch_pass.json", lambda r: r["epoch_speedup"]),
        ("pool dispatch", "BENCH_pool.json", lambda r: r["dispatch_speedup"]),
        ("simd dense", "BENCH_simd.json", lambda r: r["dense_inner_speedup"]),
        ("simd sparse", "BENCH_simd.json", lambda r: r["sparse_inner_speedup"]),
        ("numa sharded", "BENCH_numa.json", lambda r: r["sharded_speedup"]),
    ],
    "numa placement billing (sim s)": [
        ("flat", "BENCH_numa.json", lambda r: r["flat_sim_seconds"]),
        ("placement Δ", "BENCH_numa.json", lambda r: r["placement_delta_s"]),
        ("false sharing Δ", "BENCH_numa.json", lambda r: r["false_sharing_delta_s"]),
        ("bandwidth Δ", "BENCH_numa.json", lambda r: r["bandwidth_delta_s"]),
        ("all effects", "BENCH_numa.json", lambda r: r["numa_all_sim_seconds"]),
        ("sharded", "BENCH_numa.json", lambda r: r["sharded_sim_seconds"]),
    ],
    "serving latency (ms)": [
        ("p50", "BENCH_serving.json", lambda r: r["p50_ms"]),
        ("p99", "BENCH_serving.json", lambda r: r["p99_ms"]),
        ("slo", "BENCH_serving.json", lambda r: r["slo_ms"]),
    ],
}


def run_label(d: Path) -> str:
    """Date + short sha from NIGHTLY_STAMP.txt, else the directory name."""
    stamp = d / "NIGHTLY_STAMP.txt"
    if stamp.is_file():
        lines = stamp.read_text().splitlines()
        when = lines[0].strip() if lines else ""
        sha = lines[1].strip()[:9] if len(lines) > 1 else ""
        if when:
            return f"{when} {sha}".strip()
    return d.name


def load_reports(d: Path):
    """filename -> parsed dict for every readable BENCH_*.json in `d`."""
    out = {}
    for f in sorted(d.glob("BENCH_*.json")):
        try:
            rep = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"render_trends: skipping unreadable {f}: {e}", file=sys.stderr)
            continue
        if isinstance(rep, dict):
            out[f.name] = rep
    return out


def cell(reports, filename, extract) -> str:
    rep = reports.get(filename)
    if rep is None:
        return "—"
    try:
        v = extract(rep)
    except (KeyError, TypeError, ValueError):
        return "—"
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, (int, float)):
        return f"{v:.3f}" if isinstance(v, float) else str(v)
    return str(v)


def render(dirs) -> str:
    runs = [(run_label(d), load_reports(d)) for d in dirs]
    runs.sort(key=lambda t: t[0])
    lines = [
        "# Bench trends",
        "",
        "Rendered by `ci/render_trends.py` from nightly BENCH_*.json",
        f"artifacts; {len(runs)} run(s). Missing reports show as —.",
    ]
    for section, cols in METRICS.items():
        lines += ["", f"## {section}", ""]
        header = ["run"] + [name for name, _, _ in cols]
        lines.append("| " + " | ".join(header) + " |")
        lines.append("|" + "---|" * len(header))
        for label, reports in runs:
            row = [label] + [cell(reports, fname, ex) for _, fname, ex in cols]
            lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--results",
        action="append",
        required=True,
        help="results directory holding BENCH_*.json (repeatable, one per nightly run)",
    )
    ap.add_argument("--out", default="TRENDS.md", help="output markdown path")
    args = ap.parse_args(argv)

    dirs = [Path(d) for d in args.results]
    missing = [d for d in dirs if not d.is_dir()]
    if missing:
        print(f"render_trends: not a directory: {missing}", file=sys.stderr)
        return 1
    page = render(dirs)
    Path(args.out).write_text(page)
    print(f"render_trends: wrote {args.out} ({len(dirs)} run(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
