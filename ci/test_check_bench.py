"""Tests for the bench-gate checker (ci/check_bench.py).

Each gate gets a canned passing report and targeted mutations that must
fail, exercised through both the checker functions and the `main` CLI
surface (exit codes, --only selection, missing/malformed reports, step
summary writing).
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
import check_bench  # noqa: E402


def passing_reports():
    return {
        "BENCH_sparse_vs_dense.json": {"sparse_speedup": 12.5, "density": 0.005},
        "BENCH_epoch_pass.json": {"epoch_speedup": 8.0, "density": 0.004},
        "BENCH_contention.json": {
            "host_cores": 4,
            "fitted": {"kappa": 0.31, "collision_ns": 45.0},
            "tolerance": 0.3,
            "predictions": [
                {
                    "threads": 2,
                    "gated": True,
                    "measured_throughput": 1.0e7,
                    "predicted_throughput": 1.1e7,
                    "rel_err": 0.1,
                },
                {
                    "threads": 16,
                    "gated": False,
                    "measured_throughput": 2.0e7,
                    "predicted_throughput": 9.0e7,
                    "rel_err": 3.5,
                },
            ],
            "points": [
                {"threads": 1, "collision_rate": 0.0},
                {"threads": 2, "collision_rate": 0.02},
                {"threads": 4, "collision_rate": 0.05},
            ],
            "telemetry_overhead": 0.01,
            "overhead_limit": 0.05,
            "pass": True,
        },
        "BENCH_pool.json": {
            "spawn_us_per_phase": 120.0,
            "pool_us_per_phase": 10.0,
            "dispatch_speedup": 12.0,
            "dispatch_target": 5.0,
            "legacy_epochs_per_sec": 40.0,
            "pool_epochs_per_sec": 55.0,
            "e2e_speedup": 1.4,
            "pass": True,
        },
        "BENCH_distributed.json": {
            "surface": [
                {"nodes": 1, "net": "zero", "sim_seconds": 4.0},
                {"nodes": 1, "net": "lan", "sim_seconds": 4.5},
                {"nodes": 2, "net": "zero", "sim_seconds": 2.1},
                {"nodes": 2, "net": "lan", "sim_seconds": 2.8},
                {"nodes": 4, "net": "zero", "sim_seconds": 1.2},
                {"nodes": 4, "net": "lan", "sim_seconds": 2.0},
            ],
            "parity_cluster_seconds": 4.0,
            "parity_single_box_seconds": 4.0,
            "parity_pass": True,
            "sync_epochs_per_sec": 0.8,
            "async_epochs_per_sec": 1.1,
            "monotone_pass": True,
            "determinism_pass": True,
            "pass": True,
        },
        "BENCH_serving.json": {
            "slo_ms": 50.0,
            "p50_ms": 0.4,
            "p99_ms": 6.0,
            "served": 600,
            "overlap_requests": 420,
            "quiet_epochs_per_sec": 80.0,
            "loaded_epochs_per_sec": 52.0,
            "eps_ratio": 0.65,
            "eps_ratio_min": 0.25,
            "parity_quiet": "a3f1c2d4e5b60789",
            "parity_hotswap": "a3f1c2d4e5b60789",
            "parity_live": "a3f1c2d4e5b60789",
            "overload_offered": 512,
            "overload_admitted": 64,
            "overload_shed": 448,
            "vr_pass": True,
            "pass": True,
        },
        "BENCH_simd.json": {
            "d": 4096,
            "sparse_nnz": 512,
            "dot_ref_ns": 3.8,
            "dot_lanes_ns": 0.6,
            "dense_inner_ref_ns": 4.4,
            "dense_inner_lanes_ns": 1.1,
            "dense_inner_speedup": 4.0,
            "sparse_inner_ref_ns": 6.0,
            "sparse_inner_lanes_ns": 2.2,
            "sparse_inner_speedup": 2.7,
            "target_speedup": 2.0,
            "axpy_fp_ref": "1111aaaa2222bbbb",
            "axpy_fp_lanes": "1111aaaa2222bbbb",
            "fused_fp_ref": "3333cccc4444dddd",
            "fused_fp_lanes": "3333cccc4444dddd",
            "scatter_fp_ref": "5555eeee6666ffff",
            "scatter_fp_lanes": "5555eeee6666ffff",
            "dot_within_tol": True,
            "gather_dot_within_tol": True,
            "batch_parity_b1": "7777000088881111",
            "batch_parity_b4": "7777000088881111",
            "pass": True,
        },
        "BENCH_numa.json": {
            "bench": "numa",
            "threads": 8,
            "sockets": 2,
            "flat_sim_seconds": 2.0,
            "placement_delta_s": 0.4,
            "false_sharing_delta_s": 0.08,
            "bandwidth_delta_s": 0.05,
            "numa_all_sim_seconds": 2.55,
            "sharded_sim_seconds": 2.2,
            "sharded_speedup": 1.16,
            "ratio_floor": 1.05,
            "real_sharded": True,
            "real_cut": 12,
            "real_replica_tau": 3,
            "real_effective_tau": 5,
            "real_tau_feasible": True,
            "pass": True,
        },
    }


@pytest.fixture
def results_dir(tmp_path):
    for name, rep in passing_reports().items():
        (tmp_path / name).write_text(json.dumps(rep))
    return tmp_path


def run_main(results_dir, only=None):
    argv = ["--results", str(results_dir)]
    if only:
        argv += ["--only", only]
    return check_bench.main(argv)


def test_all_gates_pass_on_canned_reports(results_dir, capsys):
    assert run_main(results_dir) == 0
    assert "all bench gates passed" in capsys.readouterr().out


@pytest.mark.parametrize(
    "filename,mutate,expect",
    [
        ("BENCH_sparse_vs_dense.json", {"sparse_speedup": 3.0}, "sparse"),
        ("BENCH_epoch_pass.json", {"epoch_speedup": 2.0}, "epoch"),
        ("BENCH_epoch_pass.json", {"density": 0.5}, "epoch"),
        ("BENCH_pool.json", {"dispatch_speedup": 1.2}, "pool"),
        ("BENCH_pool.json", {"e2e_speedup": 0.9}, "pool"),
        ("BENCH_pool.json", {"pass": False}, "pool"),
        ("BENCH_contention.json", {"telemetry_overhead": 0.2}, "contention"),
        ("BENCH_contention.json", {"pass": False}, "contention"),
        ("BENCH_distributed.json", {"parity_pass": False}, "distributed"),
        ("BENCH_distributed.json", {"async_epochs_per_sec": 0.5}, "distributed"),
        ("BENCH_distributed.json", {"determinism_pass": False}, "distributed"),
        ("BENCH_distributed.json", {"pass": False}, "distributed"),
        ("BENCH_serving.json", {"p99_ms": 80.0}, "serving"),
        ("BENCH_serving.json", {"served": 0}, "serving"),
        ("BENCH_serving.json", {"eps_ratio": 0.1}, "serving"),
        ("BENCH_serving.json", {"parity_live": "deadbeefdeadbeef"}, "serving"),
        ("BENCH_serving.json", {"overload_shed": 447}, "serving"),
        ("BENCH_serving.json", {"vr_pass": False}, "serving"),
        ("BENCH_serving.json", {"pass": False}, "serving"),
        ("BENCH_simd.json", {"dense_inner_speedup": 1.4}, "simd"),
        ("BENCH_simd.json", {"sparse_inner_speedup": 1.9}, "simd"),
        ("BENCH_simd.json", {"axpy_fp_lanes": "deadbeefdeadbeef"}, "simd"),
        ("BENCH_simd.json", {"scatter_fp_ref": "deadbeefdeadbeef"}, "simd"),
        ("BENCH_simd.json", {"dot_within_tol": False}, "simd"),
        ("BENCH_simd.json", {"gather_dot_within_tol": False}, "simd"),
        ("BENCH_simd.json", {"batch_parity_b4": "deadbeefdeadbeef"}, "simd"),
        ("BENCH_simd.json", {"pass": False}, "simd"),
        ("BENCH_numa.json", {"sharded_speedup": 1.01}, "numa"),
        ("BENCH_numa.json", {"placement_delta_s": 0.0}, "numa"),
        ("BENCH_numa.json", {"false_sharing_delta_s": -0.01}, "numa"),
        ("BENCH_numa.json", {"bandwidth_delta_s": 0.0}, "numa"),
        ("BENCH_numa.json", {"real_sharded": False}, "numa"),
        ("BENCH_numa.json", {"real_cut": 0}, "numa"),
        ("BENCH_numa.json", {"pass": False}, "numa"),
    ],
)
def test_threshold_violations_fail(results_dir, capsys, filename, mutate, expect):
    path = results_dir / filename
    rep = json.loads(path.read_text())
    rep.update(mutate)
    path.write_text(json.dumps(rep))
    assert run_main(results_dir) == 1
    assert expect in capsys.readouterr().err


def test_gated_prediction_error_fails_but_oversubscribed_does_not(results_dir, capsys):
    path = results_dir / "BENCH_contention.json"
    rep = json.loads(path.read_text())
    # the ungated point is already 3.5x off and must not trip the gate
    assert run_main(results_dir) == 0
    capsys.readouterr()
    rep["predictions"][0]["rel_err"] = 0.9  # gated point now out of tolerance
    path.write_text(json.dumps(rep))
    assert run_main(results_dir) == 1
    assert "prediction off by" in capsys.readouterr().err


def test_collision_rate_monotonicity_only_below_core_count(results_dir, capsys):
    path = results_dir / "BENCH_contention.json"
    rep = json.loads(path.read_text())
    # a dip beyond host_cores is ignored...
    rep["points"].append({"threads": 16, "collision_rate": 0.0})
    path.write_text(json.dumps(rep))
    assert run_main(results_dir) == 0
    capsys.readouterr()
    # ...a dip within it fails
    rep["points"][2]["collision_rate"] = 0.001
    path.write_text(json.dumps(rep))
    assert run_main(results_dir) == 1
    assert "not monotone" in capsys.readouterr().err


def test_distributed_free_network_must_scale(results_dir, capsys):
    path = results_dir / "BENCH_distributed.json"
    rep = json.loads(path.read_text())
    # a slowdown on the LAN surface is fine (that's the network knee)...
    rep["surface"][5]["sim_seconds"] = 9.0
    path.write_text(json.dumps(rep))
    assert run_main(results_dir, only="distributed") == 0
    capsys.readouterr()
    # ...but the free-network surface must stay monotone in node count
    rep["surface"][4]["sim_seconds"] = 3.0
    path.write_text(json.dumps(rep))
    assert run_main(results_dir, only="distributed") == 1
    assert "not monotone in nodes" in capsys.readouterr().err


def test_only_selects_gates(results_dir, capsys):
    (results_dir / "BENCH_pool.json").write_text(json.dumps({"pass": False}))
    assert run_main(results_dir, only="sparse,epoch") == 0
    capsys.readouterr()
    assert run_main(results_dir, only="pool") == 1


def test_unknown_gate_is_a_usage_error(results_dir):
    with pytest.raises(SystemExit) as e:
        run_main(results_dir, only="frobnicate")
    assert e.value.code == 2


def test_missing_report_fails_with_filename(tmp_path, capsys):
    assert run_main(tmp_path, only="sparse") == 1
    assert "missing report" in capsys.readouterr().err


def test_malformed_report_fails_not_crashes(results_dir, capsys):
    (results_dir / "BENCH_pool.json").write_text(json.dumps({"unexpected": True}))
    assert run_main(results_dir, only="pool") == 1
    assert "malformed report" in capsys.readouterr().err


def test_step_summary_lines_written(results_dir, tmp_path, monkeypatch):
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert run_main(results_dir) == 0
    lines = summary.read_text().splitlines()
    assert len(lines) == len(check_bench.GATES)
    assert all(line.startswith("✅") for line in lines)
    summary.write_text("")
    (results_dir / "BENCH_sparse_vs_dense.json").write_text(
        json.dumps({"sparse_speedup": 1.0, "density": 0.005})
    )
    assert run_main(results_dir, only="sparse") == 1
    assert summary.read_text().startswith("❌")
