"""Tests for the nightly trend renderer (ci/render_trends.py)."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
import render_trends  # noqa: E402
from test_check_bench import passing_reports  # noqa: E402


def make_run(tmp_path, name, stamp=None, reports=None):
    d = tmp_path / name
    d.mkdir()
    for fname, rep in (reports if reports is not None else passing_reports()).items():
        (d / fname).write_text(json.dumps(rep))
    if stamp:
        (d / "NIGHTLY_STAMP.txt").write_text(stamp)
    return d


def run_main(dirs, out):
    argv = []
    for d in dirs:
        argv += ["--results", str(d)]
    argv += ["--out", str(out)]
    return render_trends.main(argv)


def test_renders_all_sections_from_canned_reports(tmp_path):
    d = make_run(tmp_path, "night1", stamp="2026-08-07T03:47:00Z\nabcdef0123456789\n")
    out = tmp_path / "TRENDS.md"
    assert run_main([d], out) == 0
    page = out.read_text()
    for section in render_trends.METRICS:
        assert f"## {section}" in page
    # stamp label: date + 9-char sha
    assert "2026-08-07T03:47:00Z abcdef012" in page
    # a few values carried through with 3-decimal formatting
    assert "12.500" in page  # sparse_speedup
    assert "0.310" in page  # fitted kappa
    rep = passing_reports()["BENCH_numa.json"]
    assert f"{rep['sharded_speedup']:.3f}" in page


def test_runs_sort_by_label_and_missing_reports_dash(tmp_path):
    newer = make_run(tmp_path, "b", stamp="2026-08-07T03:47:00Z\nbbbb\n")
    # older artifact predates the numa/simd benches entirely
    partial = {
        k: v
        for k, v in passing_reports().items()
        if k in ("BENCH_sparse_vs_dense.json", "BENCH_pool.json")
    }
    older = make_run(tmp_path, "a", stamp="2026-08-01T03:47:00Z\naaaa\n", reports=partial)
    out = tmp_path / "TRENDS.md"
    assert run_main([newer, older], out) == 0
    page = out.read_text()
    assert page.index("2026-08-01") < page.index("2026-08-07"), "rows sort chronologically"
    older_speedup_row = next(
        line for line in page.splitlines() if line.startswith("| 2026-08-01") and "12.500" in line
    )
    assert "—" in older_speedup_row, "absent benches render as em dash, not an error"


def test_unstamped_dir_uses_its_name(tmp_path):
    d = make_run(tmp_path, "nightly-bench-41")
    out = tmp_path / "TRENDS.md"
    assert run_main([d], out) == 0
    assert "| nightly-bench-41 |" in out.read_text()


def test_malformed_report_skipped_not_crash(tmp_path, capsys):
    d = make_run(tmp_path, "night1")
    (d / "BENCH_numa.json").write_text("{not json")
    out = tmp_path / "TRENDS.md"
    assert run_main([d], out) == 0
    assert "skipping unreadable" in capsys.readouterr().err
    # numa columns degrade to dashes; other sections still render
    assert "12.500" in out.read_text()


def test_missing_directory_is_an_error(tmp_path, capsys):
    out = tmp_path / "TRENDS.md"
    assert run_main([tmp_path / "no-such"], out) == 1
    assert "not a directory" in capsys.readouterr().err
    assert not out.exists()
