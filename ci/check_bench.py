#!/usr/bin/env python3
"""Bench-gate checks for CI and nightly runs.

Validates the BENCH_*.json reports emitted by `cargo bench --bench
bench_micro` against the repo's performance contracts:

* sparse-vs-dense — the O(nnz) inner iteration must be >= 5x the O(d)
  path at text-shaped density (DESIGN.md §3).
* epoch-pass — the sparse epoch pass must be >= 5x dense at <= 1% density
  (DESIGN.md §5).
* contention — the calibrated collision model must predict measured
  contended throughput within tolerance on gated thread counts, collision
  rates must be monotone up to the host core count, and sampled telemetry
  must stay under its overhead limit (DESIGN.md §6).
* pool — waking the persistent worker pool must beat per-phase thread
  spawning by its dispatch target, and improve end-to-end epochs/sec
  (DESIGN.md §8).
* distributed — the cluster simulator must scale monotonically in node
  count below the network knee, reproduce the single-box simulator
  bit-for-bit at m=1 with a zero-cost network, run async epoch
  boundaries at least as fast as sync under high RPC latency, and be
  bit-deterministic per seed (DESIGN.md §10).
* serving — train-while-serving must hold its p99 latency SLO at nominal
  load while continual AsySVRG trains, keep epochs/sec within the bound
  the report states, train bit-identical with and without readers (both
  consistency modes), shed deterministically at the admission cap, and
  keep variance reduction alive across ingest rounds (DESIGN.md §11).
* simd — the 8-lane kernels must beat their strict scalar twins by the
  target factor on the reduction-dominated inner-loop composites, stay
  bit-identical on the elementwise kernels (fingerprint equality), keep
  reductions inside the derived ulp envelope, and the fused b=4 batch
  must train bit-identical to b=1 at one thread (DESIGN.md §12).
* numa — on a simulated 2-socket machine over Zipfian data the hot-head
  replica sharding must beat the unsharded billing by the report's ratio
  floor, each placement effect (cross-socket collisions, false sharing,
  interconnect bandwidth) must bill a strictly positive delta in
  isolation, and the real replica layer must have genuinely sharded with
  a non-trivial head cut (DESIGN.md §13). Host-wider SIMD is a warning in
  the simd report, never a failure here.

Usage: check_bench.py [--results rust/results] [--only sparse,pool]

Exits 1 on the first failed gate. When $GITHUB_STEP_SUMMARY is set, a
pass/fail line per gate is appended there too.
"""

import argparse
import json
import os
import sys
from pathlib import Path


class GateFailure(Exception):
    """A bench contract was violated (message explains which and by how much)."""


def check_sparse_vs_dense(rep, log):
    speedup = rep["sparse_speedup"]
    log(f"sparse inner-iteration speedup: {speedup:.1f}x (density {rep['density']:.4%})")
    if speedup < 5.0:
        raise GateFailure(f"sparse fast path only {speedup:.1f}x (target >= 5x)")


def check_epoch_pass(rep, log):
    es = rep["epoch_speedup"]
    log(f"sparse epoch-pass speedup: {es:.1f}x (density {rep['density']:.4%})")
    if rep["density"] > 0.01:
        raise GateFailure(f"epoch bench density {rep['density']:.4%} above 1%")
    if es < 5.0:
        raise GateFailure(f"sparse epoch pass only {es:.1f}x (target >= 5x)")


def check_contention(rep, log):
    cores = int(rep["host_cores"])
    log(
        f"contention: fitted kappa={rep['fitted']['kappa']:.4f} "
        f"collision_ns={rep['fitted']['collision_ns']:.2f} ({cores} cores)"
    )
    for pred in rep["predictions"]:
        tag = "gated" if pred["gated"] else "oversubscribed (informational)"
        log(
            f"  p={int(pred['threads'])}: measured {pred['measured_throughput']:.3e} "
            f"predicted {pred['predicted_throughput']:.3e} err {pred['rel_err']:.1%} [{tag}]"
        )
        if pred["gated"] and pred["rel_err"] > rep["tolerance"]:
            raise GateFailure(
                f"p={int(pred['threads'])}: prediction off by {pred['rel_err']:.1%} "
                f"(tolerance {rep['tolerance']:.0%})"
            )
    rates = [m["collision_rate"] for m in rep["points"] if m["threads"] <= cores]
    for lo, hi in zip(rates, rates[1:]):
        if hi < lo - 0.01:
            raise GateFailure(f"collision rate not monotone across gated threads: {rates}")
    ov = rep["telemetry_overhead"]
    log(f"  telemetry overhead: {ov:+.2%} (limit {rep['overhead_limit']:.0%})")
    if ov >= rep["overhead_limit"]:
        raise GateFailure(f"telemetry overhead {ov:.2%} >= {rep['overhead_limit']:.0%}")
    if not rep["pass"]:
        raise GateFailure("contention bench reported overall FAIL")


def check_pool(rep, log):
    log(
        f"pool dispatch: spawn {rep['spawn_us_per_phase']:.1f}us vs "
        f"wake {rep['pool_us_per_phase']:.1f}us -> {rep['dispatch_speedup']:.1f}x"
    )
    log(
        f"pool end-to-end: legacy {rep['legacy_epochs_per_sec']:.1f} vs "
        f"pool {rep['pool_epochs_per_sec']:.1f} epochs/s -> {rep['e2e_speedup']:.2f}x"
    )
    if rep["dispatch_speedup"] < rep["dispatch_target"]:
        raise GateFailure(
            f"pool dispatch only {rep['dispatch_speedup']:.1f}x "
            f"(target >= {rep['dispatch_target']:.0f}x)"
        )
    if rep["e2e_speedup"] <= 1.0:
        raise GateFailure(f"pool end-to-end {rep['e2e_speedup']:.2f}x is not an improvement")
    if not rep["pass"]:
        raise GateFailure("pool bench reported overall FAIL")


def check_distributed(rep, log):
    secs = [
        (int(pt["nodes"]), pt["sim_seconds"])
        for pt in rep["surface"]
        if pt["net"] == "zero"
    ]
    secs.sort()
    log(f"distributed free-network surface: {['m=%d: %.4fs' % s for s in secs]}")
    for (m_lo, t_lo), (m_hi, t_hi) in zip(secs, secs[1:]):
        if t_hi > t_lo * 1.02:
            raise GateFailure(
                f"free-network sim time not monotone in nodes: "
                f"m={m_hi} takes {t_hi:.4f}s vs m={m_lo} at {t_lo:.4f}s"
            )
    if not rep["parity_pass"]:
        raise GateFailure(
            f"m=1/zero-network parity broken: cluster "
            f"{rep['parity_cluster_seconds']!r}s vs single-box "
            f"{rep['parity_single_box_seconds']!r}s"
        )
    log(
        f"  boundary under high latency: sync {rep['sync_epochs_per_sec']:.2f} "
        f"vs async {rep['async_epochs_per_sec']:.2f} epochs/s"
    )
    if rep["async_epochs_per_sec"] < rep["sync_epochs_per_sec"]:
        raise GateFailure(
            f"async boundary slower than sync under latency: "
            f"{rep['async_epochs_per_sec']:.2f} < {rep['sync_epochs_per_sec']:.2f} epochs/s"
        )
    if not rep["determinism_pass"]:
        raise GateFailure("distributed run not bit-deterministic per seed")
    if not rep["pass"]:
        raise GateFailure("distributed bench reported overall FAIL")


def check_serving(rep, log):
    # thresholds live in the report so the bench and the gate can't drift
    log(
        f"serving latency: p50 {rep['p50_ms']:.3f}ms p99 {rep['p99_ms']:.3f}ms "
        f"over {int(rep['served'])} served (SLO {rep['slo_ms']:.0f}ms, "
        f"{int(rep['overlap_requests'])} due during training)"
    )
    if int(rep["served"]) <= 0:
        raise GateFailure("serving run served zero requests")
    if rep["p99_ms"] > rep["slo_ms"]:
        raise GateFailure(f"p99 {rep['p99_ms']:.3f}ms exceeds the {rep['slo_ms']:.0f}ms SLO")
    log(
        f"serving throughput: {rep['quiet_epochs_per_sec']:.1f} quiet vs "
        f"{rep['loaded_epochs_per_sec']:.1f} loaded epochs/s "
        f"({rep['eps_ratio']:.2f}x, floor {rep['eps_ratio_min']:.2f}x)"
    )
    if rep["eps_ratio"] < rep["eps_ratio_min"]:
        raise GateFailure(
            f"training throughput degraded to {rep['eps_ratio']:.2f}x under load "
            f"(floor {rep['eps_ratio_min']:.2f}x)"
        )
    if not (rep["parity_quiet"] == rep["parity_hotswap"] == rep["parity_live"]):
        raise GateFailure(
            f"readers changed the trained bits: quiet {rep['parity_quiet']} "
            f"hotswap {rep['parity_hotswap']} live {rep['parity_live']}"
        )
    shed_expect = int(rep["overload_offered"]) - int(rep["overload_admitted"])
    log(
        f"serving overload: {int(rep['overload_offered'])} offered, "
        f"{int(rep['overload_admitted'])} admitted, {int(rep['overload_shed'])} shed"
    )
    if int(rep["overload_shed"]) != shed_expect or shed_expect <= 0:
        raise GateFailure(
            f"admission control off: shed {int(rep['overload_shed'])} != "
            f"offered-admitted {shed_expect}"
        )
    if not rep["vr_pass"]:
        raise GateFailure("variance reduction did not survive ingest rounds")
    if not rep["pass"]:
        raise GateFailure("serving bench reported overall FAIL")


def check_simd(rep, log):
    # thresholds live in the report so the bench and the gate can't drift
    target = rep["target_speedup"]
    log(
        f"simd inner-loop speedups: dense {rep['dense_inner_speedup']:.2f}x "
        f"sparse {rep['sparse_inner_speedup']:.2f}x (target >= {target:.1f}x)"
    )
    if rep["dense_inner_speedup"] < target:
        raise GateFailure(
            f"dense inner loop only {rep['dense_inner_speedup']:.2f}x "
            f"(target >= {target:.1f}x)"
        )
    if rep["sparse_inner_speedup"] < target:
        raise GateFailure(
            f"sparse inner loop only {rep['sparse_inner_speedup']:.2f}x "
            f"(target >= {target:.1f}x)"
        )
    for kernel in ("axpy", "fused", "scatter"):
        if rep[f"{kernel}_fp_ref"] != rep[f"{kernel}_fp_lanes"]:
            raise GateFailure(
                f"{kernel} lanes not bit-identical to ref: "
                f"{rep[f'{kernel}_fp_ref']} vs {rep[f'{kernel}_fp_lanes']}"
            )
    if not rep["dot_within_tol"]:
        raise GateFailure("dot reduction outside its ulp envelope")
    if not rep["gather_dot_within_tol"]:
        raise GateFailure("gather_dot reduction outside its ulp envelope")
    if rep["batch_parity_b1"] != rep["batch_parity_b4"]:
        raise GateFailure(
            f"fused b=4 batch diverged from b=1 at p=1: "
            f"{rep['batch_parity_b1']} vs {rep['batch_parity_b4']}"
        )
    log(f"simd parity: elementwise bit-identical, batch b=4 == b=1 ({rep['batch_parity_b1']})")
    if not rep["pass"]:
        raise GateFailure("simd bench reported overall FAIL")


def check_numa(rep, log):
    # thresholds live in the report so the bench and the gate can't drift
    floor = rep["ratio_floor"]
    log(
        f"numa sharded speedup: {rep['sharded_speedup']:.3f}x "
        f"(floor >= {floor:.2f}x; flat {rep['flat_sim_seconds']:.4f}s, "
        f"all-effects {rep['numa_all_sim_seconds']:.4f}s, "
        f"sharded {rep['sharded_sim_seconds']:.4f}s)"
    )
    if rep["sharded_speedup"] < floor:
        raise GateFailure(
            f"hot-head sharding only {rep['sharded_speedup']:.3f}x over unsharded "
            f"(floor >= {floor:.2f}x)"
        )
    for effect in ("placement", "false_sharing", "bandwidth"):
        delta = rep[f"{effect}_delta_s"]
        log(f"  {effect} delta: {delta:+.4f} sim s")
        if delta <= 0.0:
            raise GateFailure(
                f"{effect} effect billed {delta:+.4f}s in isolation (must be > 0: "
                f"an ablatable effect that prices nothing is not modeling anything)"
            )
    if not rep["real_sharded"] or int(rep["real_cut"]) <= 0:
        raise GateFailure(
            f"real replica layer did not shard (sharded={rep['real_sharded']}, "
            f"cut={int(rep['real_cut'])})"
        )
    log(
        f"  real replica run: cut={int(rep['real_cut'])} "
        f"replica_tau={int(rep['real_replica_tau'])} "
        f"effective_tau={int(rep['real_effective_tau'])} "
        f"feasible={rep['real_tau_feasible']}"
    )
    if not rep["pass"]:
        raise GateFailure("numa bench reported overall FAIL")


# gate name -> (report filename, checker)
GATES = {
    "sparse": ("BENCH_sparse_vs_dense.json", check_sparse_vs_dense),
    "epoch": ("BENCH_epoch_pass.json", check_epoch_pass),
    "contention": ("BENCH_contention.json", check_contention),
    "pool": ("BENCH_pool.json", check_pool),
    "distributed": ("BENCH_distributed.json", check_distributed),
    "serving": ("BENCH_serving.json", check_serving),
    "simd": ("BENCH_simd.json", check_simd),
    "numa": ("BENCH_numa.json", check_numa),
}


def append_step_summary(line):
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if path:
        with open(path, "a", encoding="utf-8") as f:
            f.write(line + "\n")


def run_gates(results_dir, only, log=print):
    """Run the selected gates; returns the list of failure messages."""
    failures = []
    for name in only:
        filename, checker = GATES[name]
        path = Path(results_dir) / filename
        if not path.is_file():
            failures.append(f"{name}: missing report {path}")
            append_step_summary(f"❌ bench gate `{name}`: missing {filename}")
            continue
        try:
            checker(json.loads(path.read_text()), log)
        except GateFailure as e:
            failures.append(f"{name}: {e}")
            append_step_summary(f"❌ bench gate `{name}`: {e}")
        except (KeyError, TypeError, ValueError) as e:
            failures.append(f"{name}: malformed report {filename} ({e!r})")
            append_step_summary(f"❌ bench gate `{name}`: malformed report ({e!r})")
        else:
            append_step_summary(f"✅ bench gate `{name}` passed")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--results",
        default="rust/results",
        help="directory holding the BENCH_*.json reports (default: rust/results)",
    )
    ap.add_argument(
        "--only",
        default=",".join(GATES),
        help=f"comma list of gates to run (default: all of {','.join(GATES)})",
    )
    args = ap.parse_args(argv)
    only = [g.strip() for g in args.only.split(",") if g.strip()]
    unknown = [g for g in only if g not in GATES]
    if unknown:
        ap.error(f"unknown gate(s) {unknown}; choose from {','.join(GATES)}")
    failures = run_gates(args.results, only)
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    if failures:
        return 1
    print(f"all bench gates passed: {', '.join(only)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
